//! Typed view of `artifacts/manifest.json` — the contract between
//! `python/compile/aot.py` (producer) and the Rust runtime (consumer).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    /// Output leaves (unnamed: dtype + shape), in tuple order.
    pub outputs: Vec<IoSpec>,
}

/// Mirror of `python/compile/configs.py::ModelConfig` + parameter order.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub family: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    /// (param name, shape) in artifact input order.
    pub params: Vec<(String, Vec<usize>)>,
}

impl ModelSpec {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|(n, _)| n == name)
    }
    pub fn n_params_elems(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }
}

/// Sparsity metadata for the sliced latency artifacts.
#[derive(Debug, Clone)]
pub struct LatencySpec {
    pub sparsity: f64,
    pub f_s: usize,
    pub dk_s: usize,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub latency: BTreeMap<String, LatencySpec>,
    pub capture_leaves: Vec<String>,
    pub gradcol_leaves: Vec<String>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    Ok(j.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|d| d.as_usize().unwrap_or(0))
        .collect())
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let root = Json::parse(&text).context("parse manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in root.get("models").as_obj().context("models")? {
            let params = m
                .get("params")
                .as_arr()
                .context("params")?
                .iter()
                .map(|p| {
                    let a = p.as_arr().context("param entry")?;
                    Ok((
                        a[0].as_str().context("param name")?.to_string(),
                        shape_of(&a[1])?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            let get = |k: &str| -> Result<usize> {
                m.get(k).as_usize().with_context(|| format!("model field {k}"))
            };
            models.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    family: m.get("family").as_str().context("family")?.to_string(),
                    d_model: get("d_model")?,
                    n_heads: get("n_heads")?,
                    n_layers: get("n_layers")?,
                    d_ff: get("d_ff")?,
                    vocab: get("vocab")?,
                    seq: get("seq")?,
                    batch: get("batch")?,
                    params,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in root.get("artifacts").as_obj().context("artifacts")? {
            let inputs = a
                .get("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|e| {
                    let t = e.as_arr().context("input entry")?;
                    Ok(IoSpec {
                        name: t[0].as_str().context("input name")?.to_string(),
                        dtype: DType::parse(t[1].as_str().context("dtype")?)?,
                        shape: shape_of(&t[2])?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .as_arr()
                .context("outputs")?
                .iter()
                .enumerate()
                .map(|(i, e)| {
                    let t = e.as_arr().context("output entry")?;
                    Ok(IoSpec {
                        name: format!("out{i}"),
                        dtype: DType::parse(t[0].as_str().context("dtype")?)?,
                        shape: shape_of(&t[1])?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a.get("file").as_str().context("file")?.to_string(),
                    inputs,
                    outputs,
                },
            );
        }

        let mut latency = BTreeMap::new();
        if let Some(obj) = root.get("latency").as_obj() {
            for (name, l) in obj {
                latency.insert(
                    name.clone(),
                    LatencySpec {
                        sparsity: l.get("sparsity").as_f64().context("sparsity")?,
                        f_s: l.get("f_s").as_usize().context("f_s")?,
                        dk_s: l.get("dk_s").as_usize().context("dk_s")?,
                    },
                );
            }
        }

        let leaves = |k: &str| -> Vec<String> {
            root.get(k)
                .as_arr()
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default()
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
            artifacts,
            latency,
            capture_leaves: leaves("capture_leaves"),
            gradcol_leaves: leaves("gradcol_leaves"),
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).with_context(|| {
            format!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn artifact_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}
