//! Typed view of `artifacts/manifest.json` — the contract between the
//! artifact generator (`python/compile/gen_host_artifacts.py`, mirroring
//! the original `aot.py` entry shapes) and the Rust runtime.
//!
//! Two artifact kinds exist:
//! * `host` — executed by the in-process host backend
//!   ([`super::host_exec`]); the manifest carries the full input/output
//!   shape contract and a small on-disk stamp file per entry.
//! * `compact` — a physically sliced model exported by
//!   `prune::prune_compact` / `fasp compact` / `fasp shard`: a
//!   self-describing `*.compact.json` spec plus either one packed
//!   `.ftns` weights file (monolithic) or per-layer shards with a
//!   checksummed shard index (sharded, stream-loadable via
//!   [`super::store`]), all under `<artifacts>/compact/`.
//!   `Manifest::load` scans that directory and registers each compact
//!   model as a first-class [`ModelSpec`] with synthesized host entries
//!   (plus per-shape Wanda-metric kernel entries for its sliced
//!   shapes), so a [`super::Session`] runs it with no masks.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype '{other}'"),
        }
    }
}

/// How an artifact executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// In-process host backend (the only executable kind in this build).
    Host,
    /// Legacy AOT HLO text for a PJRT client; kept so a drifted manifest
    /// fails with a clear message instead of a parse error.
    Hlo,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// On-disk stamp file, relative to the manifest dir. Empty for
    /// entries synthesized in-memory (compact models).
    pub file: String,
    pub kind: ArtifactKind,
    pub inputs: Vec<IoSpec>,
    /// Output leaves (unnamed: dtype + shape), in tuple order.
    pub outputs: Vec<IoSpec>,
}

/// Per-layer structural dimensions. Uniform (all equal to the model-level
/// `d_ff` / `d_model`) for dense zoo models; heterogeneous for compact
/// (physically sliced) models, where every layer keeps its own number of
/// FFN hidden units and attention V/out dims.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerDims {
    /// FFN hidden width of this layer.
    pub d_ff: usize,
    /// Attention V/out (context) width of this layer.
    pub d_ov: usize,
    /// Kept V/out dims per head (len `n_heads`, sums to `d_ov`). The
    /// Q/K head dim stays `d_model / n_heads`; only the value path is
    /// sliced (FASP skips Q/K by default).
    pub head_splits: Vec<usize>,
}

/// Mirror of `python/compile/configs.py::ModelConfig` + parameter order,
/// extended with per-layer dims for compact models.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub family: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    /// Nominal (maximum / dense) FFN width; per-layer widths live in
    /// `layer_dims`.
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    /// (param name, shape) in artifact input order.
    pub params: Vec<(String, Vec<usize>)>,
    /// Per-layer structural dims. Empty means "uniform" (every layer at
    /// `d_ff` / `d_model`) — the representation dense zoo models use.
    pub layer_dims: Vec<LayerDims>,
}

impl ModelSpec {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn param_index(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|(n, _)| n == name)
    }

    pub fn n_params_elems(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// FFN hidden width of layer `l`.
    pub fn d_ff_l(&self, l: usize) -> usize {
        self.layer_dims.get(l).map(|ld| ld.d_ff).unwrap_or(self.d_ff)
    }

    /// Attention V/out width of layer `l`.
    pub fn d_ov_l(&self, l: usize) -> usize {
        self.layer_dims.get(l).map(|ld| ld.d_ov).unwrap_or(self.d_model)
    }

    /// Kept V/out dims per head of layer `l`.
    pub fn head_splits_l(&self, l: usize) -> Vec<usize> {
        match self.layer_dims.get(l) {
            Some(ld) if !ld.head_splits.is_empty() => ld.head_splits.clone(),
            _ => vec![self.head_dim(); self.n_heads],
        }
    }

    /// True when every layer sits at the dense dims (no slicing).
    pub fn is_uniform(&self) -> bool {
        (0..self.n_layers)
            .all(|l| self.d_ff_l(l) == self.d_ff && self.d_ov_l(l) == self.d_model)
    }
}

/// Sparsity metadata for the sliced latency artifacts.
#[derive(Debug, Clone)]
pub struct LatencySpec {
    pub sparsity: f64,
    pub f_s: usize,
    pub dk_s: usize,
}

/// Where a compact model's weights live on disk.
#[derive(Debug, Clone)]
pub enum CompactStorage {
    /// One packed `.ftns` file (the classic format).
    Monolithic {
        /// Absolute path of the packed-weights `.ftns` file.
        weights_path: PathBuf,
    },
    /// One `.ftns` shard per layer plus an embed/head shard, with a
    /// checksummed shard index (stream-loadable via
    /// [`crate::runtime::store::ShardedWeights`]).
    Sharded {
        /// Directory the shard files live in.
        dir: PathBuf,
        index: crate::runtime::store::ShardIndex,
    },
}

impl CompactStorage {
    pub fn label(&self) -> &'static str {
        match self {
            CompactStorage::Monolithic { .. } => "monolithic",
            CompactStorage::Sharded { .. } => "sharded",
        }
    }

    /// Load the full packed weights of `spec` from this storage — the one
    /// implementation behind `Manifest::compact_weights` and
    /// `model::compact::load_compact`. Sharded artifacts are assembled
    /// shard by shard (checksum-verified).
    pub fn load_weights(&self, spec: &ModelSpec) -> Result<crate::model::Weights> {
        match self {
            CompactStorage::Monolithic { weights_path } => {
                anyhow::ensure!(
                    weights_path.exists(),
                    "compact '{}': weights file {} missing",
                    spec.name,
                    weights_path.display()
                );
                crate::model::Weights::load(spec, weights_path).with_context(|| {
                    format!(
                        "load compact weights {} (truncated or corrupt?)",
                        weights_path.display()
                    )
                })
            }
            CompactStorage::Sharded { dir, index } => {
                crate::runtime::store::ShardedWeights::open(
                    spec.clone(),
                    dir.clone(),
                    index.clone(),
                )?
                .assemble()
                .with_context(|| format!("assemble sharded compact '{}'", spec.name))
            }
        }
    }
}

/// A registered compact model artifact (spec lives in `models`).
#[derive(Debug, Clone)]
pub struct CompactInfo {
    pub base_model: String,
    pub sparsity: f64,
    pub storage: CompactStorage,
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub latency: BTreeMap<String, LatencySpec>,
    /// Compact models registered from `<dir>/compact/*.compact.json`.
    pub compact: BTreeMap<String, CompactInfo>,
    pub capture_leaves: Vec<String>,
    pub gradcol_leaves: Vec<String>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    Ok(j.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|d| d.as_usize().unwrap_or(0))
        .collect())
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "read {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let root = Json::parse(&text).context("parse manifest.json")?;

        let mut models = BTreeMap::new();
        for (name, m) in root.get("models").as_obj().context("models")? {
            let params = m
                .get("params")
                .as_arr()
                .context("params")?
                .iter()
                .map(|p| {
                    let a = p.as_arr().context("param entry")?;
                    Ok((
                        a[0].as_str().context("param name")?.to_string(),
                        shape_of(&a[1])?,
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            let get = |k: &str| -> Result<usize> {
                m.get(k).as_usize().with_context(|| format!("model field {k}"))
            };
            models.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    family: m.get("family").as_str().context("family")?.to_string(),
                    d_model: get("d_model")?,
                    n_heads: get("n_heads")?,
                    n_layers: get("n_layers")?,
                    d_ff: get("d_ff")?,
                    vocab: get("vocab")?,
                    seq: get("seq")?,
                    batch: get("batch")?,
                    params,
                    layer_dims: Vec::new(), // uniform
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in root.get("artifacts").as_obj().context("artifacts")? {
            let inputs = a
                .get("inputs")
                .as_arr()
                .context("inputs")?
                .iter()
                .map(|e| {
                    let t = e.as_arr().context("input entry")?;
                    Ok(IoSpec {
                        name: t[0].as_str().context("input name")?.to_string(),
                        dtype: DType::parse(t[1].as_str().context("dtype")?)?,
                        shape: shape_of(&t[2])?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .as_arr()
                .context("outputs")?
                .iter()
                .enumerate()
                .map(|(i, e)| {
                    let t = e.as_arr().context("output entry")?;
                    Ok(IoSpec {
                        name: format!("out{i}"),
                        dtype: DType::parse(t[0].as_str().context("dtype")?)?,
                        shape: shape_of(&t[1])?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let kind = match a.get("kind").as_str() {
                None | Some("host") => ArtifactKind::Host,
                Some("hlo") => ArtifactKind::Hlo,
                Some(other) => bail!("artifact '{name}': unknown kind '{other}'"),
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a.get("file").as_str().context("file")?.to_string(),
                    kind,
                    inputs,
                    outputs,
                },
            );
        }

        let mut latency = BTreeMap::new();
        if let Some(obj) = root.get("latency").as_obj() {
            for (name, l) in obj {
                latency.insert(
                    name.clone(),
                    LatencySpec {
                        sparsity: l.get("sparsity").as_f64().context("sparsity")?,
                        f_s: l.get("f_s").as_usize().context("f_s")?,
                        dk_s: l.get("dk_s").as_usize().context("dk_s")?,
                    },
                );
            }
        }

        let leaves = |k: &str| -> Vec<String> {
            root.get(k)
                .as_arr()
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default()
        };

        let mut manifest = Manifest {
            dir: dir.to_path_buf(),
            models,
            artifacts,
            latency,
            compact: BTreeMap::new(),
            capture_leaves: leaves("capture_leaves"),
            gradcol_leaves: leaves("gradcol_leaves"),
        };

        // Register compact exports (physically sliced models). Stale
        // `*.tmp` debris (a crashed sharded publish, see
        // `store::write_shards`) is cleared before the scan so it can
        // never shadow or trip the registration pass.
        let cdir = dir.join("compact");
        if cdir.is_dir() {
            let sweep = crate::runtime::store::clean_stale_tmp(&cdir);
            if sweep.skipped > 0 {
                crate::warn!(
                    "compact scan: {} stale .tmp entries under {} could not \
                     be removed",
                    sweep.skipped,
                    cdir.display()
                );
            }
            let mut paths: Vec<PathBuf> = std::fs::read_dir(&cdir)
                .with_context(|| format!("scan {}", cdir.display()))?
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .filter(|p| {
                    p.file_name()
                        .and_then(|n| n.to_str())
                        .map(|n| n.ends_with(".compact.json"))
                        .unwrap_or(false)
                })
                .collect();
            paths.sort();
            for p in paths {
                // register_compact rejects duplicate names itself, so two
                // descriptors declaring the same model fail loudly here
                manifest.register_compact(&p)?;
            }
        }
        Ok(manifest)
    }

    /// Register one compact model artifact from its `*.compact.json`
    /// descriptor: validates the spec, checks every weights/shard file
    /// exists, inserts the model, synthesizes its host entries and the
    /// per-shape Wanda-metric kernel entries for its sliced shapes.
    ///
    /// A model name registers exactly once: a compact artifact colliding
    /// with a zoo model — or with another compact descriptor declaring
    /// the same name — is a hard error, never a silent overwrite.
    pub fn register_compact(&mut self, path: &Path) -> Result<String> {
        let (spec, info) = crate::model::compact::load_compact_spec(path)
            .with_context(|| format!("register compact artifact {}", path.display()))?;
        match &info.storage {
            CompactStorage::Monolithic { weights_path } => {
                anyhow::ensure!(
                    weights_path.exists(),
                    "compact artifact '{}' points at missing weights file {} — \
                     delete the stale descriptor {} or restore the weights file",
                    spec.name,
                    weights_path.display(),
                    path.display()
                );
            }
            CompactStorage::Sharded { dir, index } => {
                for s in &index.shards {
                    let p = dir.join(&s.file);
                    anyhow::ensure!(
                        p.exists(),
                        "compact artifact '{}' points at missing shard file {} — \
                         delete the stale descriptor {} or restore the shard",
                        spec.name,
                        p.display(),
                        path.display()
                    );
                }
            }
        }
        if self.models.contains_key(&spec.name) {
            if self.compact.contains_key(&spec.name) {
                bail!(
                    "compact model '{}' is declared by multiple descriptors — \
                     {} duplicates an already-registered artifact; remove the \
                     stale one",
                    spec.name,
                    path.display()
                );
            }
            bail!(
                "compact artifact '{}' collides with an existing model — rename \
                 or delete {}",
                spec.name,
                path.display()
            );
        }
        let name = spec.name.clone();
        for art in synthesize_model_entries(&spec) {
            self.artifacts.insert(art.name.clone(), art);
        }
        // compact-aware kernel metrics: give every sliced shape its own
        // wanda_metric entry so re-pruning a compact model routes through
        // the kernel path instead of warning + host fallback
        for art in synthesize_metric_entries(&spec) {
            self.artifacts.entry(art.name.clone()).or_insert(art);
        }
        self.models.insert(name.clone(), spec);
        self.compact.insert(name.clone(), info);
        Ok(name)
    }

    /// Load the full packed weights of a registered compact model (either
    /// storage format; sharded artifacts are assembled shard by shard).
    pub fn compact_weights(&self, name: &str) -> Result<crate::model::Weights> {
        let info = self
            .compact
            .get(name)
            .with_context(|| format!("'{name}' is not a registered compact model"))?;
        info.storage.load_weights(self.model(name)?)
    }

    /// Open the streaming store of a registered *sharded* compact model.
    pub fn compact_store(
        &self,
        name: &str,
    ) -> Result<crate::runtime::store::ShardedWeights> {
        let info = self
            .compact
            .get(name)
            .with_context(|| format!("'{name}' is not a registered compact model"))?;
        let spec = self.model(name)?;
        match &info.storage {
            CompactStorage::Sharded { dir, index } => {
                crate::runtime::store::ShardedWeights::open(
                    spec.clone(),
                    dir.clone(),
                    index.clone(),
                )
            }
            CompactStorage::Monolithic { .. } => bail!(
                "'{name}' is a monolithic compact artifact — load it with \
                 compact_weights, or re-export sharded (`fasp shard` / \
                 `--export-sharded`) to stream it"
            ),
        }
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models.get(name).with_context(|| {
            format!(
                "model '{name}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn artifact_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

/// Build the four host entries (`fwd_loss`, `capture`, `gradcol`,
/// `train_step`) for a model spec, with exact per-layer output shapes —
/// the same contract `gen_host_artifacts.py` writes for the dense zoo.
pub(crate) fn synthesize_model_entries(spec: &ModelSpec) -> Vec<ArtifactSpec> {
    let p = spec.n_params_elems();
    let (b, t) = (spec.batch, spec.seq);
    let d = spec.d_model;
    let f32_in = |name: &str, shape: Vec<usize>| IoSpec {
        name: name.to_string(),
        dtype: DType::F32,
        shape,
    };
    let i32_in = |name: &str, shape: Vec<usize>| IoSpec {
        name: name.to_string(),
        dtype: DType::I32,
        shape,
    };
    let f32_out = |i: usize, shape: Vec<usize>| IoSpec {
        name: format!("out{i}"),
        dtype: DType::F32,
        shape,
    };

    let mut out = Vec::with_capacity(4);

    out.push(ArtifactSpec {
        name: format!("{}_fwd_loss", spec.name),
        file: String::new(),
        kind: ArtifactKind::Host,
        inputs: vec![
            f32_in("params", vec![p]),
            i32_in("tokens", vec![b, t]),
            i32_in("targets", vec![b, t]),
        ],
        outputs: vec![
            f32_out(0, vec![]),
            f32_out(1, vec![b]),
            f32_out(2, vec![b, t]),
        ],
    });

    let mut cap_outputs = Vec::new();
    for l in 0..spec.n_layers {
        let fl = spec.d_ff_l(l);
        let ol = spec.d_ov_l(l);
        let i0 = cap_outputs.len();
        cap_outputs.push(f32_out(i0, vec![d, d]));
        cap_outputs.push(f32_out(i0 + 1, vec![d, d]));
        cap_outputs.push(f32_out(i0 + 2, vec![ol, ol]));
        cap_outputs.push(f32_out(i0 + 3, vec![fl, fl]));
        cap_outputs.push(f32_out(i0 + 4, vec![d]));
        cap_outputs.push(f32_out(i0 + 5, vec![d]));
        cap_outputs.push(f32_out(i0 + 6, vec![ol]));
        cap_outputs.push(f32_out(i0 + 7, vec![fl]));
    }
    out.push(ArtifactSpec {
        name: format!("{}_capture", spec.name),
        file: String::new(),
        kind: ArtifactKind::Host,
        inputs: vec![f32_in("params", vec![p]), i32_in("tokens", vec![b, t])],
        outputs: cap_outputs,
    });

    let mut grad_outputs = Vec::new();
    for l in 0..spec.n_layers {
        let i0 = grad_outputs.len();
        grad_outputs.push(f32_out(i0, vec![spec.d_ff_l(l)]));
        grad_outputs.push(f32_out(i0 + 1, vec![spec.d_ov_l(l)]));
    }
    out.push(ArtifactSpec {
        name: format!("{}_gradcol", spec.name),
        file: String::new(),
        kind: ArtifactKind::Host,
        inputs: vec![
            f32_in("params", vec![p]),
            i32_in("tokens", vec![b, t]),
            i32_in("targets", vec![b, t]),
        ],
        outputs: grad_outputs,
    });

    out.push(ArtifactSpec {
        name: format!("{}_train_step", spec.name),
        file: String::new(),
        kind: ArtifactKind::Host,
        inputs: vec![
            f32_in("state", vec![3 * p]),
            i32_in("tokens", vec![b, t]),
            i32_in("targets", vec![b, t]),
            f32_in("t", vec![]),
            f32_in("lr", vec![]),
        ],
        outputs: vec![f32_out(0, vec![]), f32_out(1, vec![3 * p])],
    });

    out
}

/// Per-shape `wanda_metric_{m}x{n}` kernel entries for a compact model's
/// sliced shapes. The FASP pipeline scores the later matrices —
/// `fc2`/`w_down` ([d, d_ff_l]) and `wo` ([d, d_ov_l]) — and the
/// wanda_struct baseline additionally scores every operator's input
/// columns, including the transposed orientations `wv`/`fc1`/`w_gate`/
/// `w_up` ([d_ff_l | d_ov_l, d]) and `wq`/`wk` ([d, d]). The dense zoo
/// shapes ship pre-built kernel artifacts, but compact
/// (per-layer-sliced) shapes don't exist until export time —
/// synthesizing every scored orientation here (same contract as
/// `gen_host_artifacts.py` writes: inputs `w [m, n]`, `xnorm [n]`,
/// output `[n]`) closes the ROADMAP "compact-aware kernel metrics" gap,
/// so `KernelMetric` stops falling back to the shape-generic host
/// metric (and stops warning) for freshly exported models.
pub(crate) fn synthesize_metric_entries(spec: &ModelSpec) -> Vec<ArtifactSpec> {
    let d = spec.d_model;
    let mut shapes = std::collections::BTreeSet::new();
    shapes.insert((d, d));
    for l in 0..spec.n_layers {
        for x in [spec.d_ff_l(l), spec.d_ov_l(l)] {
            shapes.insert((d, x));
            shapes.insert((x, d));
        }
    }
    shapes
        .into_iter()
        .map(|(m, n)| ArtifactSpec {
            name: format!("wanda_metric_{m}x{n}"),
            file: String::new(),
            kind: ArtifactKind::Host,
            inputs: vec![
                IoSpec { name: "w".into(), dtype: DType::F32, shape: vec![m, n] },
                IoSpec { name: "xnorm".into(), dtype: DType::F32, shape: vec![n] },
            ],
            outputs: vec![IoSpec {
                name: "out0".into(),
                dtype: DType::F32,
                shape: vec![n],
            }],
        })
        .collect()
}
