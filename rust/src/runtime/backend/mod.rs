//! Pluggable execution backends for the host runtime.
//!
//! A [`Backend`] decides *how* an entry executes — today that means which
//! worker [`Pool`] the interpreter fans out on. Two implementations ship:
//!
//! * [`HostBackend`] — the single-threaded interpreter, kept as the
//!   determinism reference every other backend is measured against.
//! * [`ThreadedHostBackend`] — the same interpreter fanning out over
//!   batch rows, attention heads and matmul row blocks on a scoped
//!   worker pool ([`crate::util::pool`]), sized by `FASP_THREADS` with a
//!   sane default. All fan-outs preserve the serial reduction order, so
//!   its outputs are bit-identical to `HostBackend` (locked in by
//!   `rust/tests/test_backend.rs`).
//!
//! Backends are installed per [`super::Session`]; entry execution runs
//! inside `backend.enter()`, which scopes the backend's pool onto the
//! current thread (see [`crate::util::pool::current`]). Code outside any
//! session scope (benches poking artifacts directly, the compact
//! repacker from the CLI) sees the process-default pool instead.

use crate::util::pool::{self, Pool, PoolScope};
use once_cell::sync::OnceCell;
use std::sync::Arc;

/// An execution strategy for host entries. Implementations must be
/// deterministic: the same inputs produce bit-identical outputs on every
/// backend (see the determinism contract in `rust/tests/test_backend.rs`).
pub trait Backend: Send + Sync {
    /// Short human-readable name for logs and bench rows.
    fn name(&self) -> &'static str;

    /// The worker pool entry execution fans out on.
    fn pool(&self) -> &Arc<Pool>;

    /// Worker count (1 for the serial reference).
    fn threads(&self) -> usize {
        self.pool().workers()
    }

    /// Install this backend's pool on the current thread for the duration
    /// of the returned scope (entry execution happens inside it).
    fn enter(&self) -> PoolScope {
        pool::enter(self.pool().clone())
    }

    /// How many layer shards a streaming parameter source
    /// (`runtime::store::StreamingParams`) loads ahead of the layer
    /// currently executing. 0 = fully synchronous I/O (the serial
    /// reference); ≥ 1 overlaps shard I/O with compute on background
    /// threads. Prefetch never changes numerics — only wall-time — and a
    /// future shard-per-rank backend overrides this to pin shards to
    /// ranks.
    fn prefetch_depth(&self) -> usize {
        1
    }
}

/// The single-threaded reference interpreter.
pub struct HostBackend {
    pool: Arc<Pool>,
}

impl HostBackend {
    pub fn new() -> HostBackend {
        HostBackend { pool: pool::serial() }
    }
}

impl Default for HostBackend {
    fn default() -> Self {
        HostBackend::new()
    }
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }
    fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }
    /// The reference backend does everything on the calling thread,
    /// including shard I/O.
    fn prefetch_depth(&self) -> usize {
        0
    }
}

/// The thread-pooled interpreter: identical numerics, parallel fan-out.
pub struct ThreadedHostBackend {
    pool: Arc<Pool>,
}

impl ThreadedHostBackend {
    /// Fixed worker count (≥ 1; 1 degenerates to the serial reference).
    pub fn new(threads: usize) -> ThreadedHostBackend {
        ThreadedHostBackend { pool: Arc::new(Pool::new(threads)) }
    }

    /// Sized by `FASP_THREADS`, else the machine default (capped at 8).
    pub fn from_env() -> ThreadedHostBackend {
        ThreadedHostBackend::new(pool::default_threads())
    }
}

impl Backend for ThreadedHostBackend {
    fn name(&self) -> &'static str {
        "threaded-host"
    }
    fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }
}

/// The process-default backend, chosen once from `FASP_THREADS` / core
/// count: threaded when more than one worker is available, else the
/// serial reference. `Session::new` uses this.
pub fn default_backend() -> Arc<dyn Backend> {
    static CACHE: OnceCell<Arc<dyn Backend>> = OnceCell::new();
    CACHE
        .get_or_init(|| {
            if pool::default_threads() > 1 {
                Arc::new(ThreadedHostBackend::from_env()) as Arc<dyn Backend>
            } else {
                Arc::new(HostBackend::new()) as Arc<dyn Backend>
            }
        })
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backends_report_their_pools() {
        let h = HostBackend::new();
        assert_eq!(h.threads(), 1);
        assert_eq!(h.name(), "host");
        assert_eq!(h.prefetch_depth(), 0, "serial reference must not prefetch");
        let t = ThreadedHostBackend::new(4);
        assert_eq!(t.threads(), 4);
        assert_eq!(t.name(), "threaded-host");
        assert_eq!(t.prefetch_depth(), 1);
    }

    #[test]
    fn enter_installs_the_backend_pool() {
        let t = ThreadedHostBackend::new(3);
        {
            let _g = t.enter();
            assert_eq!(pool::current().workers(), 3);
        }
        let h = HostBackend::new();
        let _g = h.enter();
        assert_eq!(pool::current().workers(), 1);
    }
}
