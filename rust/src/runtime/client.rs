//! Thread-local PJRT CPU client. `PjRtClient` is `Rc`-backed (not
//! `Send`/`Sync`), and the whole runtime is single-threaded on this
//! 1-core testbed, so the client lives in a thread-local and every PJRT
//! call stays on the calling thread.

use anyhow::Result;
use once_cell::unsync::OnceCell;

thread_local! {
    static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
}

/// Run `f` with the shared (per-thread) CPU client.
pub fn with_client<R>(f: impl FnOnce(&xla::PjRtClient) -> Result<R>) -> Result<R> {
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let c = xla::PjRtClient::cpu()?;
            crate::debug!(
                "PJRT client: platform={} devices={}",
                c.platform_name(),
                c.device_count()
            );
            let _ = cell.set(c);
        }
        f(cell.get().unwrap())
    })
}
