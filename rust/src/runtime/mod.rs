//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the only boundary between the Rust coordinator and the
//! JAX/Pallas compute — python never runs at this point.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json` (input/output
//!   shapes, model parameter orders, capture leaf layout).
//! * [`client`] — process-wide `PjRtClient` singleton.
//! * [`executable`] — one compiled artifact: literal execution + shape
//!   checking + output unpacking.
//! * [`engine`] — model-level facade: `fwd_loss`, `capture`, `gradcol`,
//!   `train_step` (with persistent device buffers for the training state).

pub mod client;
pub mod engine;
pub mod executable;
pub mod manifest;

pub use engine::ModelEngine;
pub use executable::Artifact;
pub use manifest::{ArtifactSpec, Manifest, ModelSpec};
