//! Runtime: loads the artifact manifest and executes every entry through
//! the in-process host backend. The original PJRT/HLO boundary survives
//! as the artifact *contract* (manifest-declared shapes, opaque literals,
//! positional inputs), so the coordinator code is backend-agnostic.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json` (input/output
//!   shapes, model parameter orders, capture leaf layout, per-layer dims,
//!   compact-model registration).
//! * [`literal`] — the typed value currency (owned host arrays).
//! * [`host_exec`] — the host entry interpreter (forward, capture,
//!   gradcol, fused Adam train step, kernels, sliced layers).
//! * [`executable`] — one loaded artifact: literal execution + shape
//!   checking + output validation + perf counters.
//! * [`engine`] — model-level facade: `fwd_loss`, `capture`, `gradcol`,
//!   `train_step` (with a reusable packed-params literal).

pub mod engine;
pub mod executable;
pub mod host_exec;
pub mod literal;
pub mod manifest;

pub use engine::ModelEngine;
pub use executable::Artifact;
pub use literal::Literal;
pub use manifest::{ArtifactSpec, Manifest, ModelSpec};
