//! Runtime: loads the artifact manifest and executes every entry through
//! a pluggable host backend. The original PJRT/HLO boundary survives as
//! the artifact *contract* (manifest-declared shapes, opaque literals,
//! positional inputs), so the coordinator code is backend-agnostic.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json` (input/output
//!   shapes, model parameter orders, capture leaf layout, per-layer dims,
//!   compact-model registration).
//! * [`literal`] — the typed value currency (owned host arrays). Never
//!   constructed outside runtime/: callers hold [`session::PackedParams`]
//!   and [`session::TrainState`] instead.
//! * [`backend`] — the [`Backend`] trait plus [`HostBackend`] (serial
//!   determinism reference) and [`ThreadedHostBackend`] (scoped worker
//!   pool, `FASP_THREADS`, bit-identical outputs).
//! * [`host_exec`] — the host entry interpreter (forward, capture,
//!   gradcol, fused Adam train step, kernels, sliced layers); fans out
//!   over batch rows / attention heads on the backend's pool.
//! * [`executable`] — one loaded artifact: literal execution + shape
//!   checking + output validation + perf counters.
//! * [`session`] — the typed model session: `fwd_loss`, `capture`,
//!   `gradcol`, `train_step` over packed params / train state, the
//!   layer-streaming `fwd_loss_streamed` / `capture_streamed` entries,
//!   and the KV-cached decode surface (`prefill` / `decode_step` /
//!   `generate` / `generate_streamed` over `model::decode`).
//! * [`store`] — the sharded compact model store: per-layer `.ftns`
//!   shards + embed/head shard with checksummed index, lazy
//!   [`ShardedWeights`] loads with residency accounting, and the
//!   background-prefetch [`store::StreamingParams`] source.

pub mod backend;
pub mod executable;
pub mod host_exec;
pub mod literal;
pub mod manifest;
pub mod session;
pub mod store;

pub use backend::{default_backend, Backend, HostBackend, ThreadedHostBackend};
pub use executable::Artifact;
pub use literal::Literal;
pub use manifest::{ArtifactSpec, CompactStorage, Manifest, ModelSpec};
pub use session::{
    CalibStats, Entry, FwdOut, GradScores, LayerStats, PackedParams, Session, TrainState,
};
pub use store::{ShardIndex, ShardedWeights, StreamSnapshot};
