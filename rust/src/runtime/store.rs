//! Sharded compact model store: one `.ftns` shard per decoder layer plus
//! an embedding/head shard, described by a shard index embedded in the
//! `*.compact.json` spec. This is what lets a multi-GB compact export
//! stream-load — the prune/eval paths touch only the layers they need,
//! with peak resident weights of O(one layer) instead of O(model) — and
//! is the seam a future shard-per-rank (tensor-parallel) backend maps
//! onto.
//!
//! Pieces:
//! * [`ShardLayout`] — the packed-vector geometry of a spec: the prefix
//!   (embeddings) / per-layer runs / tail (final norm) ranges. Layer
//!   parameters are contiguous in manifest order, so every layer shard
//!   is a contiguous slice of the monolithic packed vector.
//! * [`ShardIndex`] / [`ShardMeta`] — the on-disk index (file names,
//!   element counts, payload dtype, FNV-1a checksums of the exact file
//!   bytes), stored in the compact spec so a stale or truncated shard
//!   fails loudly. Layer shards may carry an int8 payload
//!   ([`Quant::Int8`]): group-of-64 symmetric quantization with per-
//!   group f32 scales, ~0.27× the f32 stream bytes. The embed/head
//!   shard stays f32 (it feeds the gather table). An index written
//!   before the dtype field existed loads as f32.
//! * [`write_shards`] / [`write_shards_q`] — the export side: serializes
//!   + checksums every shard on the ambient worker pool (pure per-shard
//!   work, so the bytes are pool-width-independent), then publishes via
//!   temp-file + rename.
//! * [`ShardedWeights`] — the lazy handle: per-shard loads with checksum
//!   verification, full [`ShardedWeights::assemble`] for non-streaming
//!   callers, and resident/peak-byte accounting ([`StreamSnapshot`]).
//! * [`StreamingParams`] — a [`ParamSource`] that serves the host
//!   forward layer-by-layer, keeping up to `Backend::prefetch_depth`
//!   shards ahead of the executing layer in flight on background I/O
//!   threads. Prefetch overlaps I/O with compute only; the bytes and
//!   therefore the outputs are bit-identical to the monolithic path.

use crate::model::compact::CompactModel;
use crate::model::weights::{gather_rows, linear_shorts, ParamSource, Weights};
use crate::runtime::manifest::ModelSpec;
use crate::tensor::io::TensorFile;
use crate::tensor::pack::{
    dequantize_flat_range, quantize_flat, PackedMat, Quant, Q8_GROUP,
};
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

/// FNV-1a over raw bytes — the shard checksum. Dependency-free, stable,
/// and plenty for corruption detection (not a cryptographic signature).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardKind {
    /// Embeddings + final norm (the params before the first and after the
    /// last layer in packed order). Resident for a whole forward — the
    /// tied head reuses `tok_emb` for the logits.
    Embed,
    /// All parameters of one decoder layer.
    Layer(usize),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMeta {
    pub kind: ShardKind,
    /// File name relative to the compact spec's directory.
    pub file: String,
    /// Element count of the shard's packed tensor (logical f32 elems,
    /// whatever the payload dtype).
    pub elems: usize,
    /// On-disk payload dtype. F32 shards are `.ftns` tensor files; Int8
    /// shards are FQ8S blobs (q bytes + per-group f32 scales). The
    /// checksum always covers the written bytes, so corruption detection
    /// is dtype-agnostic.
    pub dtype: Quant,
    /// FNV-1a of the shard file's exact bytes.
    pub checksum: u64,
}

impl ShardMeta {
    /// Exact on-disk payload bytes this shard's tensor data occupies
    /// (f32: 4·elems; int8: q bytes + scale table + blob header).
    pub fn payload_bytes(&self) -> usize {
        match self.dtype {
            Quant::F32 => self.elems * 4,
            Quant::Int8 => {
                FQ8S_HEADER + self.elems + ((self.elems + Q8_GROUP - 1) / Q8_GROUP) * 4
            }
        }
    }
}

/// The shard index written into the compact spec: embed shard first,
/// then layer shards in order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardIndex {
    pub shards: Vec<ShardMeta>,
}

impl ShardIndex {
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.shards
                .iter()
                .map(|s| {
                    let mut fields: Vec<(&str, Json)> = vec![(
                        "kind",
                        Json::Str(
                            match s.kind {
                                ShardKind::Embed => "embed",
                                ShardKind::Layer(_) => "layer",
                            }
                            .to_string(),
                        ),
                    )];
                    if let ShardKind::Layer(l) = s.kind {
                        fields.push(("layer", Json::Num(l as f64)));
                    }
                    fields.push(("file", Json::Str(s.file.clone())));
                    fields.push(("elems", Json::Num(s.elems as f64)));
                    fields.push(("dtype", Json::Str(s.dtype.label().to_string())));
                    fields.push(("checksum", Json::Str(format!("{:016x}", s.checksum))));
                    Json::obj(fields)
                })
                .collect(),
        )
    }

    pub fn from_json(j: &Json) -> Result<ShardIndex> {
        let arr = j.as_arr().context("shard index is not an array")?;
        let mut shards = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let kind = match e.get("kind").as_str() {
                Some("embed") => ShardKind::Embed,
                Some("layer") => ShardKind::Layer(
                    e.get("layer")
                        .as_usize()
                        .with_context(|| format!("shard {i}: 'layer' field"))?,
                ),
                other => bail!("shard {i}: unknown shard kind {other:?}"),
            };
            let file = e
                .get("file")
                .as_str()
                .with_context(|| format!("shard {i}: 'file' field"))?
                .to_string();
            let elems = e
                .get("elems")
                .as_usize()
                .with_context(|| format!("shard {i}: 'elems' field"))?;
            // indices written before quantized shards existed carry no
            // dtype field: those stores are f32 by construction
            let dtype = match e.get("dtype").as_str() {
                None => Quant::F32,
                Some(s) => Quant::parse(s)
                    .with_context(|| format!("shard {i}: unknown dtype '{s}'"))?,
            };
            let csum = e
                .get("checksum")
                .as_str()
                .with_context(|| format!("shard {i}: 'checksum' field"))?;
            let checksum = u64::from_str_radix(csum, 16)
                .with_context(|| format!("shard {i}: bad checksum '{csum}'"))?;
            shards.push(ShardMeta { kind, file, elems, dtype, checksum });
        }
        Ok(ShardIndex { shards })
    }

    /// The index must declare exactly one embed shard plus one shard per
    /// layer, in order, with the element counts the spec implies.
    pub fn validate(&self, model: &str, layout: &ShardLayout) -> Result<()> {
        let want = 1 + layout.layers.len();
        anyhow::ensure!(
            self.shards.len() == want,
            "compact '{model}': shard index has {} entries for {} layers \
             (+1 embed shard) — index/layer-count mismatch",
            self.shards.len(),
            layout.layers.len()
        );
        anyhow::ensure!(
            self.shards[0].kind == ShardKind::Embed,
            "compact '{model}': first shard must be the embed/head shard, \
             got {:?}",
            self.shards[0].kind
        );
        anyhow::ensure!(
            self.shards[0].elems == layout.embed_elems(),
            "compact '{model}': embed shard declares {} elems, spec wants {}",
            self.shards[0].elems,
            layout.embed_elems()
        );
        anyhow::ensure!(
            self.shards[0].dtype == Quant::F32,
            "compact '{model}': embed shard must be f32 (it feeds the \
             gather table), got {}",
            self.shards[0].dtype.label()
        );
        for l in 0..layout.layers.len() {
            let s = &self.shards[1 + l];
            anyhow::ensure!(
                s.kind == ShardKind::Layer(l),
                "compact '{model}': shard {} is {:?}, want layer {l} — \
                 shard index out of order",
                1 + l,
                s.kind
            );
            anyhow::ensure!(
                s.elems == layout.layer_elems(l),
                "compact '{model}' layer {l}: shard declares {} elems, \
                 spec wants {} — index/layer-count mismatch",
                s.elems,
                layout.layer_elems(l)
            );
            anyhow::ensure!(
                s.dtype == self.quant(),
                "compact '{model}' layer {l}: shard dtype {} differs from \
                 layer 0's {} — mixed-dtype stores are not supported",
                s.dtype.label(),
                self.quant().label()
            );
        }
        Ok(())
    }

    /// The store's layer-shard dtype (layer shards are validated
    /// uniform; an index with no layer shards is f32).
    pub fn quant(&self) -> Quant {
        self.shards.get(1).map(|s| s.dtype).unwrap_or(Quant::F32)
    }
}

/// Canonical shard file name for `model`.
pub fn shard_file(model: &str, kind: ShardKind) -> String {
    match kind {
        ShardKind::Embed => format!("{model}.embed.ftns"),
        ShardKind::Layer(l) => format!("{model}.layer{l:03}.ftns"),
    }
}

/// Packed-vector geometry of a spec: `[prefix | layer 0 | … | layer L-1
/// | tail]`. Derived by scanning `spec.params`, so it holds for any
/// family and any per-layer (compact) dims; non-contiguous layer
/// parameter orders are rejected up front.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardLayout {
    /// Packed range of the params before the first layer (embeddings).
    pub prefix: (usize, usize),
    /// Packed range of each layer's params.
    pub layers: Vec<(usize, usize)>,
    /// Packed range of the params after the last layer (final norm).
    pub tail: (usize, usize),
}

impl ShardLayout {
    pub fn of(spec: &ModelSpec) -> Result<ShardLayout> {
        let mut off = 0usize;
        let mut prefix_end: Option<usize> = None;
        let mut layers: Vec<(usize, usize)> = Vec::new();
        let mut tail_start: Option<usize> = None;
        for (name, shape) in &spec.params {
            let n: usize = shape.iter().product();
            let layer = name
                .strip_prefix("layers.")
                .and_then(|r| r.split('.').next())
                .and_then(|s| s.parse::<usize>().ok());
            match layer {
                Some(l) => {
                    anyhow::ensure!(
                        tail_start.is_none(),
                        "model '{}': layer param '{name}' appears after the \
                         tail params — cannot shard a non-contiguous layout",
                        spec.name
                    );
                    if prefix_end.is_none() {
                        prefix_end = Some(off);
                    }
                    if l == layers.len() {
                        if let Some(prev) = layers.last() {
                            anyhow::ensure!(
                                prev.1 == off,
                                "model '{}': gap before layer {l} params",
                                spec.name
                            );
                        }
                        layers.push((off, off + n));
                    } else if l + 1 == layers.len() {
                        anyhow::ensure!(
                            layers[l].1 == off,
                            "model '{}': layer {l} params are not contiguous",
                            spec.name
                        );
                        layers[l].1 = off + n;
                    } else {
                        bail!(
                            "model '{}': layer params out of order at '{name}'",
                            spec.name
                        );
                    }
                }
                None => {
                    if prefix_end.is_some() && tail_start.is_none() {
                        tail_start = Some(off);
                    }
                }
            }
            off += n;
        }
        anyhow::ensure!(
            layers.len() == spec.n_layers,
            "model '{}': found {} layer param runs for {} layers",
            spec.name,
            layers.len(),
            spec.n_layers
        );
        let prefix_end = prefix_end.unwrap_or(off);
        let tail_start = tail_start.unwrap_or(off);
        Ok(ShardLayout {
            prefix: (0, prefix_end),
            layers,
            tail: (tail_start, off),
        })
    }

    pub fn embed_elems(&self) -> usize {
        (self.prefix.1 - self.prefix.0) + (self.tail.1 - self.tail.0)
    }

    pub fn layer_elems(&self, l: usize) -> usize {
        self.layers[l].1 - self.layers[l].0
    }

    pub fn max_layer_elems(&self) -> usize {
        self.layers.iter().map(|(a, b)| b - a).max().unwrap_or(0)
    }

    pub fn total_elems(&self) -> usize {
        self.tail.1
    }
}

/// Outcome of a [`clean_stale_tmp`] sweep: debris removed vs debris
/// that *could not* be removed and is still sitting in the store dir
/// (locked, permission-denied, or a directory squatting on a `.tmp`
/// name).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TmpSweep {
    pub removed: usize,
    pub skipped: usize,
}

/// Remove stale `*.tmp` leftovers under `dir` — debris from an earlier
/// publish that wrote its temp file but died before (or during) the
/// rename. Temp files are never valid store content, so scans and
/// writers alike may clear them. Removal failures are counted, not
/// swallowed: a non-zero [`TmpSweep::skipped`] tells operators debris
/// survived the sweep. An unreadable dir reports an empty sweep — the
/// caller's own I/O will surface real errors.
pub fn clean_stale_tmp(dir: &Path) -> TmpSweep {
    let mut sweep = TmpSweep::default();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return sweep;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        let is_tmp = path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.ends_with(".tmp"))
            .unwrap_or(false);
        if !is_tmp {
            continue;
        }
        match std::fs::remove_file(&path) {
            Ok(()) => sweep.removed += 1,
            Err(_) => sweep.skipped += 1,
        }
    }
    sweep
}

/// Int8 shard blob: `b"FQ8S"` magic, logical element count (u64 LE),
/// quant group size (u32 LE), the i8 codes, then the per-group f32
/// scales (LE) — no padding. ~elems + 4·⌈elems/group⌉ bytes vs 4·elems
/// for f32.
const FQ8S_MAGIC: &[u8; 4] = b"FQ8S";
/// Fixed FQ8S header bytes: magic + elems (u64) + group (u32).
const FQ8S_HEADER: usize = 4 + 8 + 4;

/// Quantize a flat f32 shard payload into an FQ8S blob. Deterministic
/// (serial per-element math), so shard bytes — and their checksums —
/// are pool-width-independent.
fn encode_fq8s(data: &[f32]) -> Vec<u8> {
    let (q, scales) = quantize_flat(data, Q8_GROUP);
    let mut out = Vec::with_capacity(FQ8S_HEADER + q.len() + scales.len() * 4);
    out.extend_from_slice(FQ8S_MAGIC);
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    out.extend_from_slice(&(Q8_GROUP as u32).to_le_bytes());
    for &v in &q {
        out.push(v as u8);
    }
    for &s in &scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    out
}

/// Parse an FQ8S blob back into (codes, scales, group). Every malformed
/// shape — bad magic, short header, truncated or oversized payload —
/// is a structural `Err` (never a panic: this sits on the serve path's
/// shard-load route).
fn decode_fq8s(bytes: &[u8], path: &Path) -> Result<(Vec<i8>, Vec<f32>, usize)> {
    anyhow::ensure!(
        bytes.len() >= FQ8S_HEADER,
        "shard {}: int8 blob shorter than its {FQ8S_HEADER}-byte header",
        path.display()
    );
    anyhow::ensure!(
        &bytes[..4] == FQ8S_MAGIC,
        "shard {}: bad int8 blob magic {:02x?}",
        path.display(),
        &bytes[..4]
    );
    let mut e8 = [0u8; 8];
    e8.copy_from_slice(&bytes[4..12]);
    let elems = u64::from_le_bytes(e8) as usize;
    let mut g4 = [0u8; 4];
    g4.copy_from_slice(&bytes[12..16]);
    let group = u32::from_le_bytes(g4) as usize;
    anyhow::ensure!(group >= 1, "shard {}: zero quant group", path.display());
    let groups = (elems + group - 1) / group;
    let want = FQ8S_HEADER + elems + groups * 4;
    anyhow::ensure!(
        bytes.len() == want,
        "shard {}: int8 blob is {} bytes, header implies {want} — \
         truncated or corrupt shard file",
        path.display(),
        bytes.len()
    );
    let q: Vec<i8> = bytes[FQ8S_HEADER..FQ8S_HEADER + elems]
        .iter()
        .map(|&b| b as i8)
        .collect();
    let mut scales = Vec::with_capacity(groups);
    for c in bytes[FQ8S_HEADER + elems..].chunks_exact(4) {
        let mut s4 = [0u8; 4];
        s4.copy_from_slice(c);
        scales.push(f32::from_le_bytes(s4));
    }
    Ok((q, scales, group))
}

/// Write one shard file per entry of the canonical index for `cm` under
/// `dir` (created on demand). Serialization + checksumming fan out on
/// the ambient worker pool — per-shard work is pure, so the bytes are
/// identical for any pool width. Files publish via temp-file + rename;
/// a failed rename removes its temp file instead of leaking
/// `<shard>.tmp` next to live store content, and stale `*.tmp` debris
/// from older crashed publishes is cleared up front.
/// Returns the index to embed in the compact spec.
pub fn write_shards(dir: &Path, cm: &CompactModel) -> Result<ShardIndex> {
    write_shards_q(dir, cm, Quant::F32)
}

/// [`write_shards`] with an explicit layer-shard payload dtype.
/// `Quant::Int8` writes layer shards as FQ8S blobs (group-of-64
/// symmetric quantization, ~0.27× the f32 bytes); the embed/head shard
/// is always f32 — it feeds the token gather table directly.
pub fn write_shards_q(dir: &Path, cm: &CompactModel, quant: Quant) -> Result<ShardIndex> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create {}", dir.display()))?;
    let sweep = clean_stale_tmp(dir);
    if sweep.skipped > 0 {
        crate::warn!(
            "sharded export: {} stale .tmp entries under {} could not be \
             removed",
            sweep.skipped,
            dir.display()
        );
    }
    let layout = ShardLayout::of(&cm.spec)?;
    let packed = &cm.weights.packed.data;
    anyhow::ensure!(
        packed.len() == layout.total_elems(),
        "sharded export: packed length {} != spec total {}",
        packed.len(),
        layout.total_elems()
    );
    let kinds: Vec<ShardKind> = std::iter::once(ShardKind::Embed)
        .chain((0..layout.layers.len()).map(ShardKind::Layer))
        .collect();
    let pool = crate::util::pool::current();
    let blobs: Vec<Result<Vec<u8>>> = pool.map(kinds.len(), |i| {
        let data: Vec<f32> = match kinds[i] {
            ShardKind::Embed => {
                let mut v = Vec::with_capacity(layout.embed_elems());
                v.extend_from_slice(&packed[layout.prefix.0..layout.prefix.1]);
                v.extend_from_slice(&packed[layout.tail.0..layout.tail.1]);
                v
            }
            ShardKind::Layer(l) => {
                packed[layout.layers[l].0..layout.layers[l].1].to_vec()
            }
        };
        let dtype = match kinds[i] {
            ShardKind::Embed => Quant::F32,
            ShardKind::Layer(_) => quant,
        };
        match dtype {
            Quant::F32 => {
                let mut tf = TensorFile::new();
                let n = data.len();
                tf.insert("packed", Tensor::new(vec![n], data));
                tf.to_bytes()
            }
            Quant::Int8 => Ok(encode_fq8s(&data)),
        }
    });
    let mut shards = Vec::with_capacity(kinds.len());
    for (kind, blob) in kinds.into_iter().zip(blobs) {
        let bytes = blob?;
        let elems = match kind {
            ShardKind::Embed => layout.embed_elems(),
            ShardKind::Layer(l) => layout.layer_elems(l),
        };
        let dtype = match kind {
            ShardKind::Embed => Quant::F32,
            ShardKind::Layer(_) => quant,
        };
        let file = shard_file(&cm.spec.name, kind);
        let tmp = dir.join(format!("{file}.tmp"));
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("write {}", tmp.display()))?;
        if let Err(e) = std::fs::rename(&tmp, dir.join(&file)) {
            // the write succeeded but the publish didn't: take the temp
            // file with us instead of leaking it into the store dir
            let _ = std::fs::remove_file(&tmp);
            return Err(anyhow::Error::new(e).context(format!("publish {file}")));
        }
        shards.push(ShardMeta { kind, file, elems, dtype, checksum: fnv1a64(&bytes) });
    }
    Ok(ShardIndex { shards })
}

// ------------------------------------------------------------- residency

/// Live byte accounting for a store: every shard load adds its payload
/// bytes to `resident` (and bumps `peak`); dropping the buffer subtracts
/// them. `peak_resident_bytes` is the receipt that streaming eval never
/// materialized more than one layer (plus prefetch) of weights.
/// Pack mirrors (the per-layer packed panels + the persistent head
/// pack a `StreamingParams` builds) are accounted separately in
/// `pack_resident`/`pack_peak` — same lifecycle discipline, distinct
/// counters, so the shard-payload bound stays comparable across
/// versions while total memory remains honest.
#[derive(Default)]
struct StreamStats {
    resident: AtomicUsize,
    peak: AtomicUsize,
    pack_resident: AtomicUsize,
    pack_peak: AtomicUsize,
    loads: AtomicU64,
    load_ns: AtomicU64,
    /// Checksum-mismatch re-reads that recovered (or tried to).
    retries: AtomicU64,
}

impl StreamStats {
    fn on_load(&self, bytes: usize, ns: u64) {
        self.loads.fetch_add(1, Ordering::Relaxed);
        self.load_ns.fetch_add(ns, Ordering::Relaxed);
        let now = self.resident.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(now, Ordering::Relaxed);
    }
    fn on_drop(&self, bytes: usize) {
        self.resident.fetch_sub(bytes, Ordering::Relaxed);
    }
    fn on_pack(&self, bytes: usize) {
        let now = self.pack_resident.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.pack_peak.fetch_max(now, Ordering::Relaxed);
    }
    fn on_pack_drop(&self, bytes: usize) {
        self.pack_resident.fetch_sub(bytes, Ordering::Relaxed);
    }
}

/// A point-in-time view of a store's load/residency counters.
#[derive(Debug, Clone, Copy)]
pub struct StreamSnapshot {
    /// Layer-shard payload dtype this store streams (f32 or int8). The
    /// byte counters below measure payloads as stored, so an int8 store
    /// reports the quantized sizes.
    pub quant: Quant,
    /// Resident shard-payload bytes (raw weights).
    pub resident_bytes: usize,
    pub peak_resident_bytes: usize,
    /// Resident packed-panel bytes (the streaming pack mirrors: current
    /// + prefetched layer packs, plus the persistent head pack).
    pub pack_resident_bytes: usize,
    pub peak_pack_bytes: usize,
    pub loads: u64,
    pub load_s: f64,
    /// Shard re-reads taken after a checksum mismatch (bounded by
    /// `SHARD_RETRIES` per load; non-zero means transient corruption was
    /// seen and retried, whether or not the load ultimately succeeded).
    pub shard_retries: u64,
}

// ------------------------------------------------------------- the store

struct StoreInner {
    spec: ModelSpec,
    dir: PathBuf,
    index: ShardIndex,
    layout: ShardLayout,
    /// Param name → (packed offset, shape), spec order.
    offsets: BTreeMap<String, (usize, Vec<usize>)>,
    stats: StreamStats,
}

/// Short name → packed panel for one streamed scope (a layer, or the
/// embed shard's tied head).
type PackMap = BTreeMap<String, Arc<PackedMat>>;

/// A pack set with residency accounting, mirroring [`ShardBuf`]'s
/// discipline: bytes register in the store's pack counters at build and
/// release on drop (with the shard at `layer_done`, or with the source
/// for the persistent head pack).
struct TrackedPacks {
    packs: PackMap,
    bytes: usize,
    store: Arc<StoreInner>,
}

impl TrackedPacks {
    fn new(packs: PackMap, store: Arc<StoreInner>) -> TrackedPacks {
        let bytes: usize = packs.values().map(|p| p.bytes()).sum();
        store.stats.on_pack(bytes);
        TrackedPacks { packs, bytes, store }
    }

    fn get(&self, short: &str) -> Option<Arc<PackedMat>> {
        self.packs.get(short).cloned()
    }
}

impl Drop for TrackedPacks {
    fn drop(&mut self) {
        self.store.stats.on_pack_drop(self.bytes);
    }
}

impl StoreInner {
    /// Pack every linear weight of layer `l` straight out of its shard
    /// payload — runs on the prefetch thread while the previous layer
    /// executes, so streamed-forward packing rides the I/O overlap for
    /// free (and on the synchronous path it simply replaces the per-call
    /// transpose `matmul_bt` used to pay). Pure relayout: bytes are
    /// thread- and pool-width-independent, and register in the store's
    /// pack-residency counters. On an int8 store each weight is
    /// dequantized out of the shard and re-quantized into int8 panels
    /// (shard groups run along the flat layer vector, panel groups along
    /// k per output lane — different grids, so a requantization is
    /// unavoidable); both steps bound their error by half a scale, and
    /// the result stays deterministic for any pool width.
    fn pack_layer(inner: &Arc<StoreInner>, l: usize, buf: &ShardBuf) -> Result<TrackedPacks> {
        let (start, _end) = inner.layout.layers[l];
        let quant = inner.index.quant();
        let mut packs = PackMap::new();
        for short in linear_shorts(&inner.spec.family) {
            let name = Weights::pname(l, short);
            if let Some((off, shape)) = inner.offsets.get(&name) {
                if shape.len() == 2 {
                    let (n, k) = (shape[0], shape[1]);
                    let local = off - start;
                    let pm = match buf.as_f32() {
                        Some(data) => PackedMat::pack_bt_raw_q(
                            &data[local..local + n * k],
                            n,
                            k,
                            quant,
                        ),
                        None => {
                            let w = buf.slice_f32(local, n * k)?;
                            PackedMat::pack_bt_raw_q(&w, n, k, quant)
                        }
                    };
                    packs.insert((*short).to_string(), Arc::new(pm));
                }
            }
        }
        Ok(TrackedPacks::new(packs, inner.clone()))
    }
}

/// Bounded re-reads after a shard checksum mismatch. A mismatch can be
/// transient (a torn readback racing a republish, an injected fault);
/// re-reading gives the load that many fresh chances before the
/// mismatch becomes the caller's `Err`. Missing files, parse failures
/// and element-count mismatches are structural, not transient, and
/// never retry.
const SHARD_RETRIES: usize = 2;

/// Lazy handle on a sharded compact model. Cheap to clone (shared
/// inner); loads verify the per-shard checksum and element count, so a
/// truncated, corrupt or stale shard fails loudly, never with garbage
/// numerics.
#[derive(Clone)]
pub struct ShardedWeights {
    inner: Arc<StoreInner>,
}

/// A loaded shard's in-memory payload: raw f32, or the int8 codes +
/// per-group scales exactly as stored (dequantization happens at the
/// point of use, so resident bytes stay at the quantized size).
enum ShardPayload {
    F32(Vec<f32>),
    Int8 { q: Vec<i8>, scales: Vec<f32>, group: usize },
}

/// One loaded shard's packed payload. Dropping it releases the bytes in
/// the store's residency accounting.
pub struct ShardBuf {
    payload: ShardPayload,
    /// Logical f32 element count (q code count for int8).
    elems: usize,
    store: Arc<StoreInner>,
}

impl ShardBuf {
    /// Logical element count of the shard's packed tensor.
    pub fn elems(&self) -> usize {
        self.elems
    }

    /// Borrow the raw f32 payload — `None` for int8 shards.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match &self.payload {
            ShardPayload::F32(d) => Some(d),
            ShardPayload::Int8 { .. } => None,
        }
    }

    /// Resident bytes of the payload as held in memory.
    fn payload_bytes(&self) -> usize {
        match &self.payload {
            ShardPayload::F32(d) => d.len() * 4,
            ShardPayload::Int8 { q, scales, .. } => q.len() + scales.len() * 4,
        }
    }

    /// Materialize elements `[off, off+n)` as f32 — a copy for f32
    /// payloads, a dequantization (`q·scale`) for int8.
    pub fn slice_f32(&self, off: usize, n: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            off + n <= self.elems,
            "shard slice [{off}, {}) outside {} elems",
            off + n,
            self.elems
        );
        Ok(match &self.payload {
            ShardPayload::F32(d) => d[off..off + n].to_vec(),
            ShardPayload::Int8 { q, scales, group } => {
                dequantize_flat_range(q, scales, *group, off, n)
            }
        })
    }
}

impl Drop for ShardBuf {
    fn drop(&mut self) {
        self.store.stats.on_drop(self.payload_bytes());
    }
}

impl ShardedWeights {
    /// Open a store on `dir` with the given spec + index (both come from
    /// the compact descriptor). Validates the index geometry; shard files
    /// are only read on demand.
    pub fn open(spec: ModelSpec, dir: PathBuf, index: ShardIndex) -> Result<ShardedWeights> {
        let layout = ShardLayout::of(&spec)?;
        index.validate(&spec.name, &layout)?;
        let mut offsets = BTreeMap::new();
        let mut off = 0usize;
        for (name, shape) in &spec.params {
            let n: usize = shape.iter().product();
            offsets.insert(name.clone(), (off, shape.clone()));
            off += n;
        }
        Ok(ShardedWeights {
            inner: Arc::new(StoreInner {
                spec,
                dir,
                index,
                layout,
                offsets,
                stats: StreamStats::default(),
            }),
        })
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.inner.spec
    }

    pub fn index(&self) -> &ShardIndex {
        &self.inner.index
    }

    pub fn dir(&self) -> &Path {
        &self.inner.dir
    }

    pub fn n_shards(&self) -> usize {
        self.inner.index.shards.len()
    }

    pub fn embed_bytes(&self) -> usize {
        self.inner.layout.embed_elems() * 4
    }

    pub fn max_layer_bytes(&self) -> usize {
        self.inner.layout.max_layer_elems() * 4
    }

    pub fn total_param_bytes(&self) -> usize {
        self.inner.layout.total_elems() * 4
    }

    /// On-disk payload dtype of the layer shards.
    pub fn quant(&self) -> Quant {
        self.inner.index.quant()
    }

    /// Exact stream bytes: the sum of every shard's stored payload
    /// bytes. Equal to `total_param_bytes` (+ small headers) on an f32
    /// store; ~0.27× on int8.
    pub fn total_payload_bytes(&self) -> usize {
        self.inner.index.shards.iter().map(|s| s.payload_bytes()).sum()
    }

    /// Largest single layer shard's stored payload bytes.
    pub fn max_layer_payload_bytes(&self) -> usize {
        self.inner.index.shards[1..]
            .iter()
            .map(|s| s.payload_bytes())
            .max()
            .unwrap_or(0)
    }

    pub fn stats(&self) -> StreamSnapshot {
        let s = &self.inner.stats;
        StreamSnapshot {
            quant: self.inner.index.quant(),
            resident_bytes: s.resident.load(Ordering::Relaxed),
            peak_resident_bytes: s.peak.load(Ordering::Relaxed),
            pack_resident_bytes: s.pack_resident.load(Ordering::Relaxed),
            peak_pack_bytes: s.pack_peak.load(Ordering::Relaxed),
            loads: s.loads.load(Ordering::Relaxed),
            load_s: s.load_ns.load(Ordering::Relaxed) as f64 / 1e9,
            shard_retries: s.retries.load(Ordering::Relaxed),
        }
    }

    /// Reset the peak/load counters to the current residency (bench reps).
    pub fn reset_stats(&self) {
        let s = &self.inner.stats;
        s.peak.store(s.resident.load(Ordering::Relaxed), Ordering::Relaxed);
        s.pack_peak
            .store(s.pack_resident.load(Ordering::Relaxed), Ordering::Relaxed);
        s.loads.store(0, Ordering::Relaxed);
        s.load_ns.store(0, Ordering::Relaxed);
        s.retries.store(0, Ordering::Relaxed);
    }

    fn read_shard(&self, si: usize) -> Result<ShardBuf> {
        let meta = &self.inner.index.shards[si];
        let path = self.inner.dir.join(&meta.file);
        let t0 = std::time::Instant::now();
        let mut last: Option<anyhow::Error> = None;
        for attempt in 0..=SHARD_RETRIES {
            if attempt > 0 {
                self.inner.stats.retries.fetch_add(1, Ordering::Relaxed);
            }
            // a missing/unreadable file is not transient — no retry
            let mut bytes = std::fs::read(&path).with_context(|| {
                format!("read shard file {} — missing or unreadable", path.display())
            })?;
            crate::fault::shard_read(&mut bytes);
            let sum = fnv1a64(&bytes);
            if sum != meta.checksum {
                // transient corruption (torn readback, injected fault):
                // a fresh read may see good bytes — retry, bounded
                last = Some(anyhow::anyhow!(
                    "shard {}: checksum mismatch (file {sum:016x}, index \
                     {:016x}) — truncated or corrupt shard file \
                     (after {SHARD_RETRIES} re-reads)",
                    path.display(),
                    meta.checksum
                ));
                continue;
            }
            let payload = match meta.dtype {
                Quant::F32 => {
                    let mut tf = TensorFile::from_bytes(&bytes)
                        .with_context(|| format!("parse shard {}", path.display()))?;
                    let t = tf.tensors.remove("packed").with_context(|| {
                        format!("shard {}: missing 'packed' tensor", path.display())
                    })?;
                    anyhow::ensure!(
                        t.numel() == meta.elems,
                        "shard {}: {} elems, index says {}",
                        path.display(),
                        t.numel(),
                        meta.elems
                    );
                    ShardPayload::F32(t.data)
                }
                Quant::Int8 => {
                    let (q, scales, group) = decode_fq8s(&bytes, &path)?;
                    anyhow::ensure!(
                        q.len() == meta.elems,
                        "shard {}: {} elems, index says {}",
                        path.display(),
                        q.len(),
                        meta.elems
                    );
                    ShardPayload::Int8 { q, scales, group }
                }
            };
            let buf = ShardBuf {
                payload,
                elems: meta.elems,
                store: self.inner.clone(),
            };
            let ns = t0.elapsed().as_nanos() as u64;
            self.inner.stats.on_load(buf.payload_bytes(), ns);
            return Ok(buf);
        }
        Err(last.unwrap_or_else(|| {
            anyhow::anyhow!("shard {}: unreachable retry exit", path.display())
        }))
    }

    /// Load the embedding/head shard.
    pub fn load_embed(&self) -> Result<ShardBuf> {
        self.read_shard(0)
    }

    /// Load one layer's shard.
    pub fn load_layer(&self, l: usize) -> Result<ShardBuf> {
        anyhow::ensure!(
            l < self.inner.layout.layers.len(),
            "layer {l} out of range ({} layers)",
            self.inner.layout.layers.len()
        );
        self.read_shard(1 + l)
    }

    /// Materialize the full monolithic [`Weights`] (for non-streaming
    /// callers: re-pruning, checkpoints, equivalence tests). Shards load
    /// one at a time, so even assembly never holds two copies. An int8
    /// store assembles to its dequantized values — the exact f32 numbers
    /// every streamed read of the same store serves.
    pub fn assemble(&self) -> Result<Weights> {
        let layout = &self.inner.layout;
        let mut packed = vec![0.0f32; layout.total_elems()];
        {
            let embed = self.load_embed()?;
            let plen = layout.prefix.1 - layout.prefix.0;
            let tlen = layout.tail.1 - layout.tail.0;
            packed[layout.prefix.0..layout.prefix.1]
                .copy_from_slice(&embed.slice_f32(0, plen)?);
            packed[layout.tail.0..layout.tail.1]
                .copy_from_slice(&embed.slice_f32(plen, tlen)?);
        }
        for l in 0..layout.layers.len() {
            let shard = self.load_layer(l)?;
            packed[layout.layers[l].0..layout.layers[l].1]
                .copy_from_slice(&shard.slice_f32(0, layout.layer_elems(l))?);
        }
        Weights::from_packed(&self.inner.spec, packed)
    }
}

// ------------------------------------------------------- streaming source

fn join_shard(
    h: JoinHandle<Result<(ShardBuf, TrackedPacks)>>,
) -> Result<(ShardBuf, TrackedPacks)> {
    match h.join() {
        Ok(r) => r,
        Err(_) => bail!("shard prefetch thread panicked"),
    }
}

/// A [`ParamSource`] streaming a [`ShardedWeights`]: the embed/head
/// shard stays resident for the whole forward (with the tied logits
/// head packed once at construction); layer shards are served strictly
/// in order, each released via `layer_done` before the next is
/// requested. With `prefetch > 0`, up to `prefetch` shards ahead of the
/// current layer load **and pack** on background threads while it
/// executes — packing shard l+1 rides the existing I/O overlap, so the
/// compute thread never transposes or packs a weight. Peak shard
/// residency is the embed shard plus at most `1 + prefetch` layer
/// shards; each pack mirrors its 2-D weights (same order of bytes,
/// dropped with the shard at `layer_done`) and is accounted separately
/// in [`StreamSnapshot::pack_resident_bytes`] / `peak_pack_bytes`, so
/// total streamed memory stays an honest receipt.
pub struct StreamingParams {
    store: ShardedWeights,
    embed: ShardBuf,
    /// The tied logits head, packed once per source (survives rewinds,
    /// so a whole generation packs it exactly once).
    embed_packs: TrackedPacks,
    cur: Option<(usize, ShardBuf, TrackedPacks)>,
    /// In-flight prefetches, ascending layer order (front = next layer).
    pending: VecDeque<(usize, JoinHandle<Result<(ShardBuf, TrackedPacks)>>)>,
    /// The next layer index not yet handed to a prefetch thread.
    next_spawn: usize,
    prefetch: usize,
}

impl StreamingParams {
    pub fn new(store: &ShardedWeights, prefetch: usize) -> Result<StreamingParams> {
        let embed = store.load_embed()?;
        // the tied logits head packs at the store's dtype: on an int8
        // store it quantizes here exactly once, straight from the f32
        // embed shard (no shard-side requantization for the head)
        let quant = store.quant();
        let embed_packs = {
            let inner = &store.inner;
            let emb = embed
                .as_f32()
                .context("embed shard must carry an f32 payload")?;
            let mut packs = PackMap::new();
            if let Some((off, shape)) = inner.offsets.get("tok_emb") {
                if shape.len() == 2
                    && *off >= inner.layout.prefix.0
                    && off + shape[0] * shape[1] <= inner.layout.prefix.1
                {
                    let (v, d) = (shape[0], shape[1]);
                    let local = off - inner.layout.prefix.0;
                    packs.insert(
                        "tok_emb".to_string(),
                        Arc::new(PackedMat::pack_bt_raw_q(
                            &emb[local..local + v * d],
                            v,
                            d,
                            quant,
                        )),
                    );
                }
            }
            TrackedPacks::new(packs, inner.clone())
        };
        let mut sp = StreamingParams {
            store: store.clone(),
            embed,
            embed_packs,
            cur: None,
            pending: VecDeque::new(),
            next_spawn: 0,
            prefetch,
        };
        sp.top_up();
        Ok(sp)
    }

    /// Keep up to `prefetch` shards in flight ahead of the consumer —
    /// each background thread loads *and packs* its layer (serial pool
    /// installed: the compute pool keeps its workers).
    fn top_up(&mut self) {
        while self.prefetch > 0
            && self.pending.len() < self.prefetch
            && self.next_spawn < self.store.spec().n_layers
        {
            let l = self.next_spawn;
            let st = self.store.clone();
            // prefetch threads inherit the spawner's fault scope, so an
            // armed shard fault fires on the Nth read no matter which
            // thread performs it
            let fh = crate::fault::handle();
            self.pending.push_back((
                l,
                std::thread::spawn(move || -> Result<(ShardBuf, TrackedPacks)> {
                    let _serial = crate::util::pool::enter(crate::util::pool::serial());
                    let _faults = crate::fault::adopt(fh);
                    let buf = st.load_layer(l)?;
                    let packs = StoreInner::pack_layer(&st.inner, l, &buf)?;
                    Ok((buf, packs))
                }),
            ));
            self.next_spawn += 1;
        }
    }

    fn ensure_layer(&mut self, l: usize) -> Result<()> {
        if matches!(&self.cur, Some((cl, _, _)) if *cl == l) {
            return Ok(());
        }
        let (buf, packs) = match self.pending.pop_front() {
            Some((nl, h)) if nl == l => join_shard(h)?,
            Some((nl, h)) => {
                // drain every stale prefetch before failing
                let _ = join_shard(h);
                for (_, h) in self.pending.drain(..) {
                    let _ = join_shard(h);
                }
                bail!(
                    "streaming params read out of order: wanted layer {l}, \
                     prefetched layer {nl}"
                );
            }
            None => {
                // no prefetch in flight (depth 0, or a re-read): load +
                // pack synchronously and restart any prefetch after `l`
                self.next_spawn = self.next_spawn.max(l + 1);
                let buf = self.store.load_layer(l)?;
                let packs = StoreInner::pack_layer(&self.store.inner, l, &buf)?;
                (buf, packs)
            }
        };
        self.cur = Some((l, buf, packs)); // replaces (drops) the previous layer
        self.top_up();
        Ok(())
    }
}

impl Drop for StreamingParams {
    fn drop(&mut self) {
        for (_, h) in self.pending.drain(..) {
            let _ = h.join();
        }
    }
}

impl ParamSource for StreamingParams {
    fn spec(&self) -> &ModelSpec {
        self.store.spec()
    }

    fn get(&mut self, name: &str) -> Result<Tensor> {
        let inner = &self.store.inner;
        let (off, shape) = inner
            .offsets
            .get(name)
            .cloned()
            .with_context(|| format!("param '{name}' not found"))?;
        let n: usize = shape.iter().product();
        let lay = &inner.layout;
        let local = if off >= lay.prefix.0 && off + n <= lay.prefix.1 {
            off - lay.prefix.0
        } else if off >= lay.tail.0 && off + n <= lay.tail.1 {
            (lay.prefix.1 - lay.prefix.0) + (off - lay.tail.0)
        } else {
            bail!("param '{name}' is a layer parameter — read it via get_l");
        };
        Ok(Tensor::new(shape, self.embed.slice_f32(local, n)?))
    }

    fn get_l(&mut self, l: usize, short: &str) -> Result<Tensor> {
        self.ensure_layer(l)?;
        let name = Weights::pname(l, short);
        let inner = &self.store.inner;
        let (off, shape) = inner
            .offsets
            .get(&name)
            .cloned()
            .with_context(|| format!("param '{name}' not found"))?;
        let n: usize = shape.iter().product();
        let (start, end) = inner.layout.layers[l];
        anyhow::ensure!(
            off >= start && off + n <= end,
            "param '{name}' lies outside layer {l}'s shard range"
        );
        let buf = &self
            .cur
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("layer {l} not resident after ensure_layer"))?
            .1;
        // int8 stores dequantize here — one bounded quantization step
        // between the exported f32 values and what the forward sees
        Ok(Tensor::new(shape, buf.slice_f32(off - start, n)?))
    }

    fn get_packed(
        &mut self,
        name: &str,
    ) -> Result<Option<Arc<PackedMat>>> {
        Ok(self.embed_packs.get(name))
    }

    fn get_l_packed(
        &mut self,
        l: usize,
        short: &str,
    ) -> Result<Option<Arc<PackedMat>>> {
        self.ensure_layer(l)?;
        let packs = &self
            .cur
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("layer {l} not resident after ensure_layer"))?
            .2;
        Ok(packs.get(short))
    }

    fn embed_rows(&mut self, ids: &[i32]) -> Result<Tensor> {
        let inner = &self.store.inner;
        let (off, shape) = inner
            .offsets
            .get("tok_emb")
            .cloned()
            .context("param 'tok_emb' not found")?;
        anyhow::ensure!(shape.len() == 2, "'tok_emb' is not 2-D: {shape:?}");
        let n: usize = shape.iter().product();
        let lay = &inner.layout;
        anyhow::ensure!(
            off >= lay.prefix.0 && off + n <= lay.prefix.1,
            "'tok_emb' lies outside the embed shard"
        );
        let local = off - lay.prefix.0;
        let emb = self
            .embed
            .as_f32()
            .context("embed shard must carry an f32 payload")?;
        gather_rows(&emb[local..local + n], shape[0], shape[1], ids)
    }

    fn with_rows(
        &mut self,
        name: &str,
        row0: usize,
        count: usize,
        f: &mut dyn FnMut(&[f32]),
    ) -> Result<()> {
        // serve prefix/tail (embed-shard) params in place; layer params
        // are never row-visited by the forward
        let inner = &self.store.inner;
        let (off, shape) = inner
            .offsets
            .get(name)
            .cloned()
            .with_context(|| format!("param '{name}' not found"))?;
        anyhow::ensure!(shape.len() == 2, "'{name}' is not 2-D: {shape:?}");
        let (rows, c) = (shape[0], shape[1]);
        anyhow::ensure!(
            row0 + count <= rows,
            "rows [{row0}, {}) outside '{name}' [{rows}, {c}]",
            row0 + count
        );
        let n = rows * c;
        let lay = &inner.layout;
        let local = if off >= lay.prefix.0 && off + n <= lay.prefix.1 {
            off - lay.prefix.0
        } else if off >= lay.tail.0 && off + n <= lay.tail.1 {
            (lay.prefix.1 - lay.prefix.0) + (off - lay.tail.0)
        } else {
            bail!("param '{name}' is a layer parameter — read it via get_l");
        };
        let emb = self
            .embed
            .as_f32()
            .context("embed shard must carry an f32 payload")?;
        f(&emb[local + row0 * c..local + (row0 + count) * c]);
        Ok(())
    }

    fn layer_done(&mut self, l: usize) -> Result<()> {
        if matches!(&self.cur, Some((cl, _, _)) if *cl == l) {
            self.cur = None; // drop the shard + its packs → residency falls
        }
        Ok(())
    }

    /// Restart the in-order pass at layer 0 (the decode loop runs one
    /// pass per generated token): drain any in-flight prefetches, drop
    /// the current layer shard, and re-prime the prefetch run — the
    /// embed shard *and its packed logits head* stay resident across
    /// passes, so a whole generation packs the head exactly once.
    fn rewind(&mut self) -> Result<()> {
        for (_, h) in self.pending.drain(..) {
            let _ = h.join(); // result (and its buffer) dropped
        }
        self.cur = None;
        self.next_spawn = 0;
        self.top_up();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::compact::build_params;
    use crate::runtime::manifest::LayerDims;

    fn toy_spec(family: &str) -> ModelSpec {
        let layer_dims = vec![
            LayerDims { d_ff: 16, d_ov: 8, head_splits: vec![4, 4] },
            LayerDims { d_ff: 12, d_ov: 6, head_splits: vec![3, 3] },
        ];
        let params = build_params(family, 8, 2, 32, 16, &layer_dims);
        ModelSpec {
            name: format!("store_toy_{family}"),
            family: family.into(),
            d_model: 8,
            n_heads: 2,
            n_layers: 2,
            d_ff: 16,
            vocab: 32,
            seq: 16,
            batch: 2,
            params,
            layer_dims,
        }
    }

    #[test]
    fn clean_stale_tmp_counts_skipped_debris() {
        let dir = std::env::temp_dir().join("fasp_store_tmp_sweep");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.tmp"), b"debris").unwrap();
        std::fs::write(dir.join("keep.ftns"), b"live").unwrap();
        // a directory squatting on a .tmp name defeats remove_file even
        // as root — the locked/undeletable-debris stand-in
        std::fs::create_dir(dir.join("stale.tmp")).unwrap();
        let sweep = clean_stale_tmp(&dir);
        assert_eq!(sweep, TmpSweep { removed: 1, skipped: 1 });
        assert!(!dir.join("a.tmp").exists());
        assert!(dir.join("keep.ftns").exists(), "sweep must not touch live files");
        assert!(dir.join("stale.tmp").exists(), "skipped debris stays on disk");
        // second sweep: nothing removable left, debris still reported
        assert_eq!(clean_stale_tmp(&dir), TmpSweep { removed: 0, skipped: 1 });
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fnv_is_stable_and_input_sensitive() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a64(b"abc"), fnv1a64(b"abd"));
    }

    #[test]
    fn layout_partitions_the_packed_vector() {
        for fam in ["opt", "llama"] {
            let spec = toy_spec(fam);
            let lay = ShardLayout::of(&spec).unwrap();
            assert_eq!(lay.prefix.0, 0);
            assert_eq!(lay.layers.len(), 2);
            assert_eq!(lay.prefix.1, lay.layers[0].0);
            assert_eq!(lay.layers[0].1, lay.layers[1].0);
            assert_eq!(lay.layers[1].1, lay.tail.0);
            assert_eq!(lay.total_elems(), spec.n_params_elems());
            assert_eq!(
                lay.embed_elems() + lay.layer_elems(0) + lay.layer_elems(1),
                spec.n_params_elems()
            );
        }
    }

    #[test]
    fn index_json_roundtrip() {
        let idx = ShardIndex {
            shards: vec![
                ShardMeta {
                    kind: ShardKind::Embed,
                    file: "m.embed.ftns".into(),
                    elems: 10,
                    dtype: Quant::F32,
                    checksum: 0xdead_beef_0102_0304,
                },
                ShardMeta {
                    kind: ShardKind::Layer(0),
                    file: "m.layer000.ftns".into(),
                    elems: 20,
                    dtype: Quant::Int8,
                    checksum: 7,
                },
            ],
        };
        let re = ShardIndex::from_json(&idx.to_json()).unwrap();
        assert_eq!(re, idx);
        assert_eq!(re.quant(), Quant::Int8);
    }

    #[test]
    fn index_json_without_dtype_loads_as_f32() {
        // an index serialized before quantized shards existed: no
        // "dtype" field anywhere — must load as an f32 store
        let legacy = Json::Arr(vec![
            Json::obj(vec![
                ("kind", Json::Str("embed".into())),
                ("file", Json::Str("m.embed.ftns".into())),
                ("elems", Json::Num(10.0)),
                ("checksum", Json::Str(format!("{:016x}", 3u64))),
            ]),
            Json::obj(vec![
                ("kind", Json::Str("layer".into())),
                ("layer", Json::Num(0.0)),
                ("file", Json::Str("m.layer000.ftns".into())),
                ("elems", Json::Num(20.0)),
                ("checksum", Json::Str(format!("{:016x}", 7u64))),
            ]),
        ]);
        let idx = ShardIndex::from_json(&legacy).unwrap();
        assert!(idx.shards.iter().all(|s| s.dtype == Quant::F32));
        assert_eq!(idx.quant(), Quant::F32);
        // and a current-format serialization round-trips it unchanged
        assert_eq!(ShardIndex::from_json(&idx.to_json()).unwrap(), idx);
    }

    #[test]
    fn fq8s_blob_roundtrips_and_rejects_corruption() {
        let data: Vec<f32> =
            (0..150).map(|i| ((i * 37 % 101) as f32 - 50.0) / 9.0).collect();
        let blob = encode_fq8s(&data);
        assert_eq!(
            blob.len(),
            FQ8S_HEADER + 150 + ((150 + Q8_GROUP - 1) / Q8_GROUP) * 4
        );
        let p = Path::new("unit.fq8s");
        let (q, scales, group) = decode_fq8s(&blob, p).unwrap();
        assert_eq!(group, Q8_GROUP);
        assert_eq!(q.len(), 150);
        for (i, (&qv, &x)) in q.iter().zip(&data).enumerate() {
            let s = scales[i / group];
            assert!(
                (x - qv as f32 * s).abs() <= s * 0.5 + 1e-6,
                "elem {i}: {x} vs {}·{}",
                qv,
                s
            );
        }
        // truncated payload and bad magic are structural errors
        assert!(decode_fq8s(&blob[..blob.len() - 1], p).is_err());
        let mut bad = blob.clone();
        bad[0] = b'X';
        assert!(decode_fq8s(&bad, p).is_err());
        assert!(decode_fq8s(&blob[..6], p).is_err());
    }

    #[test]
    fn index_layer_count_mismatch_rejected() {
        let spec = toy_spec("llama");
        let lay = ShardLayout::of(&spec).unwrap();
        let idx = ShardIndex {
            shards: vec![ShardMeta {
                kind: ShardKind::Embed,
                file: "x.embed.ftns".into(),
                elems: lay.embed_elems(),
                dtype: Quant::F32,
                checksum: 0,
            }],
        };
        let err = idx.validate(&spec.name, &lay).unwrap_err();
        assert!(
            format!("{err:#}").contains("index/layer-count mismatch"),
            "{err:#}"
        );
    }

    #[test]
    fn write_open_assemble_roundtrip() {
        let spec = toy_spec("llama");
        let w = Weights::init(&spec, 9);
        let cm = CompactModel {
            spec: spec.clone(),
            weights: w.clone(),
            base_model: "toy".into(),
            sparsity: 0.0,
        };
        let dir = std::env::temp_dir().join("fasp_store_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let index = write_shards(&dir, &cm).unwrap();
        assert_eq!(index.shards.len(), 1 + spec.n_layers);
        let store = ShardedWeights::open(spec.clone(), dir.clone(), index).unwrap();
        let re = store.assemble().unwrap();
        assert_eq!(re.packed, w.packed, "assembled shards must be bit-identical");
        // residency: assembly loads shards one at a time
        let snap = store.stats();
        assert_eq!(snap.resident_bytes, 0);
        assert!(snap.peak_resident_bytes <= store.embed_bytes() + store.max_layer_bytes());
        assert_eq!(snap.loads as usize, 1 + spec.n_layers);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn streaming_source_serves_identical_tensors() {
        let spec = toy_spec("opt");
        let w = Weights::init(&spec, 11);
        let cm = CompactModel {
            spec: spec.clone(),
            weights: w.clone(),
            base_model: "toy".into(),
            sparsity: 0.0,
        };
        let dir = std::env::temp_dir().join("fasp_store_stream_unit");
        let _ = std::fs::remove_dir_all(&dir);
        let index = write_shards(&dir, &cm).unwrap();
        let store = ShardedWeights::open(spec.clone(), dir.clone(), index).unwrap();
        for prefetch in [0usize, 1, 2] {
            let mut src = StreamingParams::new(&store, prefetch).unwrap();
            // two passes over the same source: the second (post-rewind)
            // pass is how the decode loop reuses one StreamingParams per
            // generated token, prefetch pipeline included
            for pass in 0..2 {
                assert_eq!(src.get("tok_emb").unwrap(), w.get("tok_emb").unwrap());
                assert_eq!(src.get("lnf_g").unwrap(), w.get("lnf_g").unwrap());
                for l in 0..spec.n_layers {
                    for short in ["wq", "wv", "wo", "fc1", "fc2"] {
                        assert_eq!(
                            src.get_l(l, short).unwrap(),
                            w.get_l(l, short).unwrap(),
                            "pass {pass} layer {l} {short} (prefetch {prefetch})"
                        );
                    }
                    src.layer_done(l).unwrap();
                }
                src.rewind().unwrap();
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
