//! Host entry interpreter: executes every manifest artifact kind
//! in-process, with the exact input/output contracts the original AOT
//! HLO entries had (`python/compile/aot.py`). This is the runtime's
//! execution engine — model entries route through the host reference
//! forward ([`crate::model::host`]) and the manual backward
//! ([`crate::model::host_grad`]), kernel entries through the tensor ops.
//!
//! Because execution is spec-driven, a compact model's synthesized
//! entries run through the same code with per-layer dims — no masks, no
//! special cases.
//!
//! Execution fans out over batch rows and attention heads through the
//! ambient worker pool (`util::pool`), installed by the session's
//! backend (`runtime::backend`) — serial under [`crate::runtime::HostBackend`],
//! pooled under [`crate::runtime::ThreadedHostBackend`], bit-identical under both.

use super::literal::Literal;
use super::manifest::{Manifest, ModelSpec};
use crate::model::{host, host_grad, PackedWeights, Weights};
use crate::tensor::{IntTensor, Tensor};
use anyhow::{bail, Context, Result};

/// One resolvable host entry.
pub enum HostEntry {
    FwdLoss(ModelSpec),
    Capture(ModelSpec),
    GradCol(ModelSpec),
    TrainStep(ModelSpec),
    WandaMetric { n: usize },
    Gram { n: usize },
    FlashAttn { t: usize, dh: usize },
    LatencyLayer { n_heads: usize },
}

impl HostEntry {
    /// Map an artifact name onto its host implementation.
    pub fn resolve(manifest: &Manifest, name: &str) -> Result<HostEntry> {
        for (suffix, which) in [
            ("_fwd_loss", 0usize),
            ("_capture", 1),
            ("_gradcol", 2),
            ("_train_step", 3),
        ] {
            if let Some(model) = name.strip_suffix(suffix) {
                if let Some(spec) = manifest.models.get(model) {
                    let spec = spec.clone();
                    return Ok(match which {
                        0 => HostEntry::FwdLoss(spec),
                        1 => HostEntry::Capture(spec),
                        2 => HostEntry::GradCol(spec),
                        _ => HostEntry::TrainStep(spec),
                    });
                }
            }
        }
        if let Some(dims) = name.strip_prefix("wanda_metric_") {
            let (_, n) = parse_dims(dims, name)?;
            return Ok(HostEntry::WandaMetric { n });
        }
        if let Some(dims) = name.strip_prefix("gram_") {
            let (_, n) = parse_dims(dims, name)?;
            return Ok(HostEntry::Gram { n });
        }
        if let Some(dims) = name.strip_prefix("flash_attn_") {
            let (t, dh) = parse_dims(dims, name)?;
            return Ok(HostEntry::FlashAttn { t, dh });
        }
        if manifest.latency.contains_key(name) {
            let spec = manifest
                .model("llama_small")
                .context("latency artifacts need the llama_small spec")?;
            return Ok(HostEntry::LatencyLayer { n_heads: spec.n_heads });
        }
        bail!("no host implementation for artifact '{name}'")
    }

    /// Execute with shape-validated inputs (the caller, `Artifact::call`,
    /// checks shapes against the manifest first). `model` is the
    /// session's packed operator plan ([`PackedWeights`], built once per
    /// weight set by `Session::pack`): when present, the model entries
    /// run over it — resident weights + pre-packed linear panels, zero
    /// per-call weight copies or transposes — instead of rebuilding
    /// `Weights` from the params literal on every call. Both routes are
    /// bit-identical (the packed/unpacked kernel contract), so `None`
    /// (direct artifact pokes, tests) stays fully supported.
    pub fn execute(
        &self,
        inputs: &[&Literal],
        model: Option<&PackedWeights>,
    ) -> Result<Vec<Literal>> {
        match self {
            HostEntry::FwdLoss(spec) => {
                let toks = tokens_checked(inputs[1], spec.vocab, "tokens")?;
                let tgts = tokens_checked(inputs[2], spec.vocab, "targets")?;
                let nll = match checked_model(spec, model)? {
                    Some(m) => host::forward_nll_src(&mut m.source(), &toks, &tgts, false)?.0,
                    None => {
                        let w = weights_from(spec, inputs[0])?;
                        host::forward_nll(&w, &toks, &tgts, false)?.0
                    }
                };
                Ok(fwd_outputs(&nll))
            }
            HostEntry::Capture(spec) => {
                let toks = tokens_checked(inputs[1], spec.vocab, "tokens")?;
                // capture needs no targets; reuse tokens as dummies
                let caps = match checked_model(spec, model)? {
                    Some(m) => host::forward_nll_src(&mut m.source(), &toks, &toks, true)?.1,
                    None => {
                        let w = weights_from(spec, inputs[0])?;
                        host::forward_nll(&w, &toks, &toks, true)?.1
                    }
                };
                let mut out = Vec::with_capacity(caps.len() * 8);
                for cap in &caps {
                    out.push(Literal::from_tensor(&host::host_gram(&cap.ln1)));
                    out.push(Literal::from_tensor(&host::host_gram(&cap.ln2)));
                    out.push(Literal::from_tensor(&host::host_gram(&cap.attn_ctx)));
                    out.push(Literal::from_tensor(&host::host_gram(&cap.ffn_h)));
                    out.push(col_sum_literal(&cap.ln1));
                    out.push(col_sum_literal(&cap.ln2));
                    out.push(col_sum_literal(&cap.attn_ctx));
                    out.push(col_sum_literal(&cap.ffn_h));
                }
                Ok(out)
            }
            HostEntry::GradCol(spec) => {
                let toks = tokens_checked(inputs[1], spec.vocab, "tokens")?;
                let tgts = tokens_checked(inputs[2], spec.vocab, "targets")?;
                let w_fallback;
                let (w, packs) = match checked_model(spec, model)? {
                    Some(m) => (&m.w, Some(&m.packs)),
                    None => {
                        w_fallback = weights_from(spec, inputs[0])?;
                        (&w_fallback, None)
                    }
                };
                let (_, grad) = host_grad::loss_and_grad_packed(w, packs, &toks, &tgts)?;
                let scores = host_grad::taylor_scores(w, &grad)?;
                let mut out = Vec::with_capacity(scores.len() * 2);
                for (ffn, ov) in scores {
                    let nf = ffn.len();
                    let no = ov.len();
                    out.push(Literal::from_f32(&[nf], ffn));
                    out.push(Literal::from_f32(&[no], ov));
                }
                Ok(out)
            }
            HostEntry::TrainStep(spec) => {
                let state = inputs[0].as_f32()?;
                let toks = tokens_checked(inputs[1], spec.vocab, "tokens")?;
                let tgts = tokens_checked(inputs[2], spec.vocab, "targets")?;
                let t = inputs[3].as_f32()?[0];
                let lr = inputs[4].as_f32()?[0];
                let (loss, new_state) =
                    host_grad::train_step_host(spec, state, &toks, &tgts, t, lr)?;
                let n = new_state.len();
                Ok(vec![
                    Literal::scalar_f32(loss),
                    Literal::from_f32(&[n], new_state),
                ])
            }
            HostEntry::WandaMetric { n } => {
                let w = inputs[0].to_tensor()?;
                let xnorm = inputs[1].as_f32()?;
                let scores = crate::prune::metric::wanda_scores_host(&w, xnorm);
                Ok(vec![Literal::from_f32(&[*n], scores)])
            }
            HostEntry::Gram { n } => {
                let x = inputs[0].to_tensor()?;
                let g = host::host_gram(&x);
                let _ = n;
                Ok(vec![Literal::from_tensor(&g)])
            }
            HostEntry::FlashAttn { t, dh } => {
                let q = inputs[0].to_tensor()?;
                let k = inputs[1].to_tensor()?;
                let v = inputs[2].to_tensor()?;
                let ctx = host::attention(
                    1,
                    *t,
                    1,
                    *dh,
                    &[*dh],
                    &q,
                    &k,
                    &v,
                    &[],
                    &[],
                    false,
                );
                Ok(vec![Literal::from_tensor(&ctx)])
            }
            HostEntry::LatencyLayer { n_heads } => {
                let tensors: Vec<Tensor> = inputs
                    .iter()
                    .map(|l| l.to_tensor())
                    .collect::<Result<_>>()?;
                let (b, t, _) = tensors[0].dims3();
                let y = host::sliced_layer_fwd(b, t, *n_heads, &tensors)?;
                Ok(vec![Literal::from_tensor(&y)])
            }
        }
    }
}

fn parse_dims(s: &str, name: &str) -> Result<(usize, usize)> {
    let (a, b) = s
        .split_once('x')
        .with_context(|| format!("artifact '{name}': expected <m>x<n> dims"))?;
    let m = a.parse::<usize>().with_context(|| format!("artifact '{name}' dims"))?;
    let n = b.parse::<usize>().with_context(|| format!("artifact '{name}' dims"))?;
    Ok((m, n))
}

fn weights_from(spec: &ModelSpec, params: &Literal) -> Result<Weights> {
    Weights::from_packed(spec, params.as_f32()?.to_vec())
}

/// Validate a packed operator plan against the entry it is about to
/// serve: same model, same parameter count as the entry's spec. The
/// plan is built by `Session::pack` from a length-checked vector, so
/// this guards against cross-session misuse, not drift.
fn checked_model<'m>(
    spec: &ModelSpec,
    model: Option<&'m PackedWeights>,
) -> Result<Option<&'m PackedWeights>> {
    let m = match model {
        Some(m) => m,
        None => return Ok(None),
    };
    anyhow::ensure!(
        m.w.spec.name == spec.name,
        "packed weights are for model '{}', entry runs '{}'",
        m.w.spec.name,
        spec.name
    );
    anyhow::ensure!(
        m.w.packed.numel() == spec.n_params_elems(),
        "packed weights hold {} params, model wants {}",
        m.w.packed.numel(),
        spec.n_params_elems()
    );
    Ok(Some(m))
}

fn tokens_checked(lit: &Literal, vocab: usize, what: &str) -> Result<IntTensor> {
    let t = lit.to_int_tensor()?;
    validate_tokens(&t, vocab, what)?;
    Ok(t)
}

/// Every token id must be a valid vocab index (shared with the streaming
/// session entries, which bypass the literal layer).
pub(crate) fn validate_tokens(t: &IntTensor, vocab: usize, what: &str) -> Result<()> {
    for &id in &t.data {
        anyhow::ensure!(
            id >= 0 && (id as usize) < vocab,
            "{what}: token id {id} outside vocab {vocab}"
        );
    }
    Ok(())
}

/// The `fwd_loss` output summaries (mean over all tokens in f64, per-
/// sequence sums) from the per-token NLL — one implementation, so the
/// monolithic entry and the streaming path are bit-identical.
pub(crate) fn nll_summaries(nll: &Tensor) -> (f32, Vec<f32>) {
    let (b, _t) = nll.dims2();
    let mean = nll.data.iter().map(|&x| x as f64).sum::<f64>() / nll.numel() as f64;
    let seq: Vec<f32> = (0..b).map(|r| nll.row(r).iter().sum::<f32>()).collect();
    (mean as f32, seq)
}

fn fwd_outputs(nll: &Tensor) -> Vec<Literal> {
    let (b, _t) = nll.dims2();
    let (mean, seq) = nll_summaries(nll);
    vec![
        Literal::scalar_f32(mean),
        Literal::from_f32(&[b], seq),
        Literal::from_tensor(nll),
    ]
}

fn col_sum_literal(x: &Tensor) -> Literal {
    Literal::from_tensor(&crate::model::host::col_sums(x))
}
