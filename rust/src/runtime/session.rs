//! Typed model session — the single execution surface every coordinator
//! (prune pipeline, baselines, trainer, eval harness, benches) drives.
//!
//! A [`Session`] binds one model spec to a [`Backend`] and exposes the
//! four entries as typed operations: [`Session::fwd_loss`],
//! [`Session::capture`], [`Session::gradcol`], [`Session::train_step`].
//! All [`Literal`] packing and unpacking lives here, once:
//!
//! * [`PackedParams`] — the params vector panel-packed into the
//!   persistent operator plan exactly once per weight set
//!   ([`Session::pack`]): resident weights plus a `PackCache` of every
//!   linear weight and the tied logits head in the kernel layout (the
//!   artifact contract's params input is validated count-only via
//!   `In::Elems` — no redundant literal copy). Multi-batch loops, eval
//!   windows and decode tokens reuse the plan without per-call copies,
//!   transposes or re-validation.
//! * [`TrainState`] — the opaque packed Adam state `[3P]`, mutated in
//!   place by [`Session::train_step`] and only unpacked on request.
//!
//! No caller outside `runtime/` touches a `Literal` for entry I/O.
//! Artifacts load lazily (first use of each entry) and are cached for
//! the session's lifetime.
//!
//! Sharded compact models additionally stream: [`Session::fwd_loss_streamed`]
//! and [`Session::capture_streamed`] pull weights layer-by-layer from a
//! [`ShardedWeights`] store (embed/head shard + one layer shard + the
//! backend's prefetch buffer resident at a time), producing bit-identical
//! outputs to the monolithic entries.
//!
//! Autoregressive decode ([`Session::prefill`], [`Session::decode_step`],
//! [`Session::generate`], [`Session::generate_streamed`]) bypasses the
//! literal layer entirely — a per-step param upload would cost O(model)
//! per token — and drives `model::decode` over the [`PackedParams`]
//! plan (dense or compact weights, packed once at [`Session::pack`]) or
//! a streaming store (which packs each shard on its prefetch thread),
//! inside the session's backend scope. Cached decode logits are
//! bit-identical to a full-prefix re-forward on every backend
//! (`rust/tests/test_decode.rs`), and the per-token loop performs zero
//! pack/transpose work (`bench_hot_paths` packing section).

use super::backend::{default_backend, Backend};
use super::executable::{Artifact, In};
use super::literal::Literal;
use super::manifest::{Manifest, ModelSpec};
use super::store::{ShardedWeights, StreamingParams};
use crate::model::decode::{self, GenerateOpts, Generation, KvCache};
use crate::model::host;
use crate::model::spec_decode::{self, SpecGeneration, SpecOpts};
use crate::model::weights::{PackCache, PackedWeights};
use crate::model::Weights;
use crate::tensor::pack::Quant;
use crate::tensor::ops::add_assign;
use crate::tensor::{IntTensor, Tensor};
use crate::util::pool::PoolScope;
use anyhow::{Context, Result};
use once_cell::sync::OnceCell;
use std::sync::Arc;

/// The four model entries, in manifest suffix order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Entry {
    FwdLoss = 0,
    Capture = 1,
    GradCol = 2,
    TrainStep = 3,
}

impl Entry {
    pub fn suffix(self) -> &'static str {
        match self {
            Entry::FwdLoss => "fwd_loss",
            Entry::Capture => "capture",
            Entry::GradCol => "gradcol",
            Entry::TrainStep => "train_step",
        }
    }
}

/// Per-layer calibration statistics (sums over sample rows; additive
/// across batches). Mirrors `python/compile/capture.py::CAPTURE_LEAVES`.
#[derive(Clone)]
pub struct LayerStats {
    /// Gram of the qkv input (post-ln1), d×d.
    pub g_ln1: Tensor,
    /// Gram of the fc1/gate/up input (post-ln2), d×d.
    pub g_ln2: Tensor,
    /// Gram of the W_out input (attention context), d×d.
    pub g_attn: Tensor,
    /// Gram of the W_fc2/W_down input (FFN hidden), f×f.
    pub g_ffn: Tensor,
    pub m_ln1: Tensor,
    pub m_ln2: Tensor,
    pub m_attn: Tensor,
    pub m_ffn: Tensor,
}

/// Accumulated calibration statistics for a whole model.
pub struct CalibStats {
    pub layers: Vec<LayerStats>,
    /// Number of sample rows accumulated (batches × B × T).
    pub rows: usize,
}

impl CalibStats {
    /// ‖X_j‖₂ per FFN hidden unit of layer `l` (from diag of the Gram).
    pub fn ffn_xnorm(&self, l: usize) -> Vec<f32> {
        diag_sqrt(&self.layers[l].g_ffn)
    }
    /// ‖X_j‖₂ per attention-context dim of layer `l`.
    pub fn attn_xnorm(&self, l: usize) -> Vec<f32> {
        diag_sqrt(&self.layers[l].g_attn)
    }
    /// ‖X_j‖₂ per qkv-input dim (used by the Q/K ablation).
    pub fn ln1_xnorm(&self, l: usize) -> Vec<f32> {
        diag_sqrt(&self.layers[l].g_ln1)
    }
}

fn diag_sqrt(g: &Tensor) -> Vec<f32> {
    let (n, _) = g.dims2();
    (0..n).map(|i| g.at2(i, i).max(0.0).sqrt()).collect()
}

/// Fold one batch's per-layer stats into the running accumulator —
/// shared by [`Session::capture`] and [`Session::capture_streamed`] so
/// the two paths cannot drift (the streamed≡monolithic bitwise contract
/// depends on identical accumulation order).
fn accumulate_layer_stats(acc: &mut Option<Vec<LayerStats>>, layers: Vec<LayerStats>) {
    match acc {
        None => *acc = Some(layers),
        Some(acc) => {
            for (a_l, n_l) in acc.iter_mut().zip(&layers) {
                add_assign(&mut a_l.g_ln1, &n_l.g_ln1);
                add_assign(&mut a_l.g_ln2, &n_l.g_ln2);
                add_assign(&mut a_l.g_attn, &n_l.g_attn);
                add_assign(&mut a_l.g_ffn, &n_l.g_ffn);
                add_assign(&mut a_l.m_ln1, &n_l.m_ln1);
                add_assign(&mut a_l.m_ln2, &n_l.m_ln2);
                add_assign(&mut a_l.m_attn, &n_l.m_attn);
                add_assign(&mut a_l.m_ffn, &n_l.m_ffn);
            }
        }
    }
}

/// Per-layer Taylor scores for the LLM-Pruner-like baseline.
#[derive(Clone)]
pub struct GradScores {
    pub ffn: Vec<f32>,
    pub ov: Vec<f32>,
}

pub struct FwdOut {
    pub mean_nll: f32,
    pub seq_nll: Vec<f32>,
    pub tok_nll: Tensor,
}

/// The packed operator plan of one weight set, built once by
/// [`Session::pack`] and reused across entry calls and decode steps.
/// Holds two views:
///
/// * the resident [`Weights`] (original layouts: embedding gathers,
///   backward, restoration — also what the entry contract validates
///   against, via a count-only `In::Elems` input instead of a
///   redundant params-literal copy);
/// * the [`PackCache`] — every linear weight and the tied logits head
///   pre-packed in the kernel layout, so no entry or decode step pays a
///   per-call weight copy, transpose or pack ever again.
///
/// Opaque: the plan never leaves runtime/.
pub struct PackedParams {
    model: Arc<PackedWeights>,
}

impl PackedParams {
    /// Resident bytes of the pre-packed panels (the pack-cache receipt;
    /// int8 plans count quantized bytes + scale tables).
    pub fn pack_bytes(&self) -> usize {
        self.model.packs.bytes()
    }

    /// Number of pre-packed weights in the plan.
    pub fn pack_count(&self) -> usize {
        self.model.packs.count()
    }

    /// Panel dtype of the plan ([`Quant::F32`] unless built with
    /// [`Session::pack_as`]).
    pub fn quant(&self) -> Quant {
        self.model.packs.quant()
    }
}

/// The opaque packed Adam train state `[3P]` (params, m, v). Round-trips
/// through [`Session::train_step`] without host-side decomposition.
pub struct TrainState {
    lit: Literal,
}

/// One model bound to an execution backend.
pub struct Session<'m> {
    pub manifest: &'m Manifest,
    pub spec: ModelSpec,
    backend: Arc<dyn Backend>,
    entries: [OnceCell<Artifact>; 4],
}

impl<'m> Session<'m> {
    /// Open a session on the process-default backend (threaded when more
    /// than one worker is available — see `runtime::backend`).
    pub fn new(manifest: &'m Manifest, model: &str) -> Result<Self> {
        Session::with_backend(manifest, model, default_backend())
    }

    /// Open a session on an explicit backend.
    pub fn with_backend(
        manifest: &'m Manifest,
        model: &str,
        backend: Arc<dyn Backend>,
    ) -> Result<Self> {
        let spec = manifest.model(model)?.clone();
        Ok(Session {
            manifest,
            spec,
            backend,
            entries: std::array::from_fn(|_| OnceCell::new()),
        })
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Scope the session's backend onto the current thread — what every
    /// entry call does internally; exposed so adjacent bulk work (e.g.
    /// the compact repack) can share the same pool.
    pub fn exec_scope(&self) -> PoolScope {
        self.backend.enter()
    }

    fn entry(&self, e: Entry) -> Result<&Artifact> {
        let cell = &self.entries[e as usize];
        // OnceCell::get_or_try_init would move; emulate with get/set.
        if cell.get().is_none() {
            let a =
                Artifact::load(self.manifest, &format!("{}_{}", self.spec.name, e.suffix()))?;
            let _ = cell.set(a);
        }
        Ok(cell.get().unwrap())
    }

    // ------------------------------------------------------------ packing

    /// Upload a packed params vector into artifact form (length-checked)
    /// and build its packed operator plan: the weights become resident
    /// once and every linear weight (plus the tied logits head) is
    /// panel-packed exactly once, on this session's backend pool — pack
    /// bytes are pool-width-independent. Everything downstream
    /// (`fwd_loss`/`capture`/`gradcol`, `prefill`/`decode_step`/
    /// `generate`) consumes the plan with zero per-call transpose or
    /// pack work.
    pub fn pack(&self, params: &Tensor) -> Result<PackedParams> {
        // Always exact f32 — the reference every packed≡unpacked and
        // decode≡re-forward bit contract measures against. Quantized
        // plans are an explicit opt-in ([`Session::pack_as`]); `pack`
        // never reads the environment.
        self.pack_as(params, Quant::F32)
    }

    /// [`Session::pack`] with an explicit panel dtype: [`Quant::Int8`]
    /// quantizes every linear panel (and the tied logits head) at pack
    /// time — ~0.27× resident pack bytes, bounded error, deterministic
    /// (int8 outputs are bit-identical across backends/pool widths,
    /// just not bit-matched to f32). CLI entry points pass
    /// [`Quant::from_env`] here; library callers choose explicitly.
    pub fn pack_as(&self, params: &Tensor, quant: Quant) -> Result<PackedParams> {
        anyhow::ensure!(
            params.numel() == self.spec.n_params_elems(),
            "param length {} != {} ({})",
            params.numel(),
            self.spec.n_params_elems(),
            self.spec.name
        );
        let w = Weights::from_packed(&self.spec, params.data.clone())?;
        let packs = {
            let _exec = self.backend.enter();
            PackCache::build_q(&w, quant)
        };
        Ok(PackedParams { model: Arc::new(PackedWeights { w, packs }) })
    }

    // ------------------------------------------------------------ entries

    /// Teacher-forced loss on one batch.
    pub fn fwd_loss(
        &self,
        params: &PackedParams,
        tokens: &IntTensor,
        targets: &IntTensor,
    ) -> Result<FwdOut> {
        let a = self.entry(Entry::FwdLoss)?;
        let _exec = self.backend.enter();
        let leaves = a.call_packed(
            &[In::Elems(params.model.w.packed.numel()), In::I(tokens), In::I(targets)],
            Some(&params.model),
        )?;
        let mean = leaves[0].as_f32()?[0];
        let seq = leaves[1].as_f32()?.to_vec();
        let tok = a.to_tensor(2, &leaves[2])?;
        Ok(FwdOut { mean_nll: mean, seq_nll: seq, tok_nll: tok })
    }

    /// Run capture over `batches` and accumulate the per-layer stats.
    /// Accumulation is serial in batch order — backend-independent.
    pub fn capture(
        &self,
        params: &PackedParams,
        batches: &[IntTensor],
    ) -> Result<CalibStats> {
        let a = self.entry(Entry::Capture)?;
        let _exec = self.backend.enter();
        let leaves_per_layer = self.manifest.capture_leaves.len();
        let n_layers = self.spec.n_layers;
        let mut acc: Option<Vec<LayerStats>> = None;
        let mut rows = 0usize;
        for toks in batches {
            let outs = a.call_tensors_packed(
                &[In::Elems(params.model.w.packed.numel()), In::I(toks)],
                Some(&params.model),
            )?;
            anyhow::ensure!(
                outs.len() == leaves_per_layer * n_layers,
                "capture output arity"
            );
            rows += toks.numel();
            let mut layers = Vec::with_capacity(n_layers);
            for l in 0..n_layers {
                let b = l * leaves_per_layer;
                layers.push(LayerStats {
                    g_ln1: outs[b].clone(),
                    g_ln2: outs[b + 1].clone(),
                    g_attn: outs[b + 2].clone(),
                    g_ffn: outs[b + 3].clone(),
                    m_ln1: outs[b + 4].clone(),
                    m_ln2: outs[b + 5].clone(),
                    m_attn: outs[b + 6].clone(),
                    m_ffn: outs[b + 7].clone(),
                });
            }
            accumulate_layer_stats(&mut acc, layers);
        }
        Ok(CalibStats {
            layers: acc.context("capture needs at least one batch")?,
            rows,
        })
    }

    /// Taylor column scores accumulated over calibration batches.
    pub fn gradcol(
        &self,
        params: &PackedParams,
        batches: &[(IntTensor, IntTensor)],
    ) -> Result<Vec<GradScores>> {
        let a = self.entry(Entry::GradCol)?;
        let _exec = self.backend.enter();
        let n_layers = self.spec.n_layers;
        let mut acc: Vec<GradScores> = Vec::new();
        for (toks, tgts) in batches {
            let outs = a.call_tensors_packed(
                &[In::Elems(params.model.w.packed.numel()), In::I(toks), In::I(tgts)],
                Some(&params.model),
            )?;
            anyhow::ensure!(outs.len() == 2 * n_layers, "gradcol output arity");
            if acc.is_empty() {
                for l in 0..n_layers {
                    acc.push(GradScores {
                        ffn: outs[2 * l].data.clone(),
                        ov: outs[2 * l + 1].data.clone(),
                    });
                }
            } else {
                for l in 0..n_layers {
                    for (x, y) in acc[l].ffn.iter_mut().zip(&outs[2 * l].data) {
                        *x += y;
                    }
                    for (x, y) in acc[l].ov.iter_mut().zip(&outs[2 * l + 1].data) {
                        *x += y;
                    }
                }
            }
        }
        anyhow::ensure!(!acc.is_empty(), "gradcol needs at least one batch");
        Ok(acc)
    }

    // ---------------------------------------------------------- streaming

    fn check_store(&self, store: &ShardedWeights) -> Result<()> {
        anyhow::ensure!(
            store.spec().name == self.spec.name
                && store.spec().params == self.spec.params,
            "sharded store '{}' does not match session model '{}'",
            store.spec().name,
            self.spec.name
        );
        Ok(())
    }

    fn check_batch(&self, tokens: &IntTensor, targets: &IntTensor) -> Result<()> {
        let want = [self.spec.batch, self.spec.seq];
        anyhow::ensure!(
            tokens.shape == want && targets.shape == want,
            "{}: batch shapes {:?}/{:?}, model wants {:?}",
            self.spec.name,
            tokens.shape,
            targets.shape,
            want
        );
        super::host_exec::validate_tokens(tokens, self.spec.vocab, "tokens")?;
        super::host_exec::validate_tokens(targets, self.spec.vocab, "targets")?;
        Ok(())
    }

    /// Teacher-forced loss on one batch, streaming the weights layer by
    /// layer from a sharded store: the embed/head shard plus at most one
    /// layer shard (and the backend's prefetch buffer —
    /// [`Backend::prefetch_depth`]) are resident at any moment. The
    /// shards hold the monolithic packed vector's exact bytes and the
    /// arithmetic is shared with the `fwd_loss` entry, so the outputs
    /// are **bit-identical** to [`Session::fwd_loss`] on the assembled
    /// weights.
    pub fn fwd_loss_streamed(
        &self,
        store: &ShardedWeights,
        tokens: &IntTensor,
        targets: &IntTensor,
    ) -> Result<FwdOut> {
        self.check_store(store)?;
        self.check_batch(tokens, targets)?;
        let _exec = self.backend.enter();
        let mut src = StreamingParams::new(store, self.backend.prefetch_depth())?;
        let (nll, _) = host::forward_nll_src(&mut src, tokens, targets, false)?;
        let (mean_nll, seq_nll) = super::host_exec::nll_summaries(&nll);
        Ok(FwdOut { mean_nll, seq_nll, tok_nll: nll })
    }

    /// Capture over `batches`, streaming the weights per layer. Leaf
    /// construction and batch accumulation mirror the capture entry +
    /// [`Session::capture`] exactly, so the stats are bit-identical to
    /// the monolithic path while only one layer's weights are resident.
    pub fn capture_streamed(
        &self,
        store: &ShardedWeights,
        batches: &[IntTensor],
    ) -> Result<CalibStats> {
        self.check_store(store)?;
        let _exec = self.backend.enter();
        let n_layers = self.spec.n_layers;
        let mut acc: Option<Vec<LayerStats>> = None;
        let mut rows = 0usize;
        for toks in batches {
            // capture needs no targets; reuse tokens (same as the entry)
            self.check_batch(toks, toks)?;
            let mut src = StreamingParams::new(store, self.backend.prefetch_depth())?;
            let (_, caps) = host::forward_nll_src(&mut src, toks, toks, true)?;
            drop(src);
            anyhow::ensure!(caps.len() == n_layers, "capture output arity");
            rows += toks.numel();
            let layers: Vec<LayerStats> = caps
                .iter()
                .map(|cap| LayerStats {
                    g_ln1: host::host_gram(&cap.ln1),
                    g_ln2: host::host_gram(&cap.ln2),
                    g_attn: host::host_gram(&cap.attn_ctx),
                    g_ffn: host::host_gram(&cap.ffn_h),
                    m_ln1: host::col_sums(&cap.ln1),
                    m_ln2: host::col_sums(&cap.ln2),
                    m_attn: host::col_sums(&cap.attn_ctx),
                    m_ffn: host::col_sums(&cap.ffn_h),
                })
                .collect();
            accumulate_layer_stats(&mut acc, layers);
        }
        Ok(CalibStats {
            layers: acc.context("capture needs at least one batch")?,
            rows,
        })
    }

    // ------------------------------------------------------------- decode

    fn check_decode_params(&self, p: &PackedParams) -> Result<()> {
        anyhow::ensure!(
            p.model.w.spec.name == self.spec.name
                && p.model.w.spec.params == self.spec.params,
            "weights are for model '{}', session runs '{}'",
            p.model.w.spec.name,
            self.spec.name
        );
        Ok(())
    }

    fn check_prompt(&self, prompt: &IntTensor) -> Result<()> {
        anyhow::ensure!(
            prompt.shape.len() == 2 && prompt.shape[0] >= 1 && prompt.shape[1] >= 1,
            "{}: prompt shape {:?}, want [b, t] with b, t >= 1",
            self.spec.name,
            prompt.shape
        );
        super::host_exec::validate_tokens(prompt, self.spec.vocab, "prompt")?;
        Ok(())
    }

    /// Allocate a decode cache for `batch` sequences of up to `capacity`
    /// positions under this model's (per-layer, possibly sliced) dims.
    pub fn decode_cache(&self, batch: usize, capacity: usize) -> Result<KvCache> {
        KvCache::for_spec(&self.spec, batch, capacity)
    }

    /// Run the whole prompt once, populating `cache`, and return the
    /// last-position logits [b, vocab]. Decode entries run over the
    /// packed operator plan [`Session::pack`] built — the per-token hot
    /// loop does zero weight copies, transposes or packs (uploading a
    /// literal per step would copy the whole model per token; packing
    /// per step would transpose it).
    pub fn prefill(
        &self,
        params: &PackedParams,
        prompt: &IntTensor,
        cache: &mut KvCache,
    ) -> Result<Tensor> {
        self.check_decode_params(params)?;
        self.check_prompt(prompt)?;
        let _exec = self.backend.enter();
        decode::prefill_src(&mut params.model.source(), prompt, cache)
    }

    /// Process one token per sequence against the cache — O(prefix) per
    /// token, bit-identical to a full-prefix re-forward. `tokens` holds
    /// one id per cached sequence.
    pub fn decode_step(
        &self,
        params: &PackedParams,
        tokens: &IntTensor,
        cache: &mut KvCache,
    ) -> Result<Tensor> {
        self.check_decode_params(params)?;
        super::host_exec::validate_tokens(tokens, self.spec.vocab, "tokens")?;
        let _exec = self.backend.enter();
        decode::decode_step_src(&mut params.model.source(), tokens, cache)
    }

    /// Batched generation (greedy or seeded top-k) from a prompt:
    /// prefill + one cached decode step per new token, all over the
    /// packed operator plan.
    pub fn generate(
        &self,
        params: &PackedParams,
        prompt: &IntTensor,
        opts: &GenerateOpts,
    ) -> Result<Generation> {
        self.check_decode_params(params)?;
        self.check_prompt(prompt)?;
        let _exec = self.backend.enter();
        decode::generate_src(&mut params.model.source(), prompt, opts)
    }

    /// [`Session::generate`] over a caller-supplied (reusable) cache.
    /// The request is validated against the cache capacity **up front**:
    /// a prompt + `max_new` that cannot fit errs before any prefill
    /// work instead of dying on the mid-generation overflow assert.
    pub fn generate_with_cache(
        &self,
        params: &PackedParams,
        prompt: &IntTensor,
        opts: &GenerateOpts,
        cache: &mut KvCache,
    ) -> Result<Generation> {
        self.check_decode_params(params)?;
        self.check_prompt(prompt)?;
        let _exec = self.backend.enter();
        decode::generate_with_cache_src(&mut params.model.source(), prompt, opts, cache)
    }

    /// Speculative generation: `draft` — any packed model sharing the
    /// target's vocab, typically a FASP compact export of this very
    /// model — proposes up to `draft_k` tokens per round against its
    /// own (OV-sliced, strictly smaller) cache, and the target verifies
    /// all of them plus one in a single chunked forward. Greedy output
    /// is **bit-identical** to [`Session::generate`]; sampled output is
    /// distributionally exact (rejection sampling) and seed-reproducible.
    /// The draft is *not* required to be a registered sibling of this
    /// session's model — only the token space must match (checked).
    pub fn generate_speculative(
        &self,
        params: &PackedParams,
        draft: &PackedParams,
        prompt: &IntTensor,
        opts: &SpecOpts,
    ) -> Result<SpecGeneration> {
        self.check_decode_params(params)?;
        self.check_prompt(prompt)?;
        let _exec = self.backend.enter();
        spec_decode::generate_speculative_src(
            &mut params.model.source(),
            &mut draft.model.source(),
            prompt,
            opts,
        )
    }

    /// Drive the continuous-batching serve engine (`crate::serve`) to
    /// completion on this session's backend: every request decodes over
    /// the ONE shared packed plan `params` holds, through a paged KV
    /// arena with prefix-cache prompt sharing. Per-session outputs are
    /// bit-identical to [`Session::generate`] with the same prompt,
    /// sampler and seed at batch size 1.
    pub fn serve(
        &self,
        params: &PackedParams,
        requests: &[crate::serve::ServeRequest],
        cfg: &crate::serve::ServeConfig,
    ) -> Result<crate::serve::ServeReport> {
        self.check_decode_params(params)?;
        let _exec = self.backend.enter();
        crate::serve::serve(&params.model, requests, cfg)
    }

    /// [`Session::generate`] streaming the weights from a sharded store:
    /// the embed/head shard stays resident across the whole generation,
    /// layer shards stream in order with the backend's prefetch depth
    /// (the source is rewound between token passes so prefetch stays
    /// live during decode, not just prefill). Token output is
    /// bit-identical to generating from the assembled weights.
    pub fn generate_streamed(
        &self,
        store: &ShardedWeights,
        prompt: &IntTensor,
        opts: &GenerateOpts,
    ) -> Result<Generation> {
        self.check_store(store)?;
        self.check_prompt(prompt)?;
        let _exec = self.backend.enter();
        let mut src = StreamingParams::new(store, self.backend.prefetch_depth())?;
        decode::generate_src(&mut src, prompt, opts)
    }

    // ------------------------------------------------------------ training

    /// Build a fresh packed train state `[3P]` from packed params `[P]`.
    pub fn init_train(&self, params: &Tensor) -> Result<TrainState> {
        let p = params.numel();
        anyhow::ensure!(p == self.spec.n_params_elems(), "param length");
        let mut state = vec![0.0f32; 3 * p];
        state[..p].copy_from_slice(&params.data);
        Ok(TrainState { lit: Literal::from_f32(&[3 * p], state) })
    }

    /// One Adam step: replaces the state in place, returns the loss at
    /// the incoming params. The state never unpacks on the host.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        tokens: &IntTensor,
        targets: &IntTensor,
        t: f32,
        lr: f32,
    ) -> Result<f32> {
        let a = self.entry(Entry::TrainStep)?;
        let _exec = self.backend.enter();
        let t_s = Tensor::scalar(t);
        let lr_s = Tensor::scalar(lr);
        let mut leaves = a.call(&[
            In::Lit(&state.lit),
            In::I(tokens),
            In::I(targets),
            In::F(&t_s),
            In::F(&lr_s),
        ])?;
        let loss = leaves[0].as_f32()?[0];
        state.lit = leaves.remove(1);
        Ok(loss)
    }

    /// Extract packed params `[P]` from a train state.
    pub fn train_params(&self, state: &TrainState) -> Result<Tensor> {
        let all = state.lit.as_f32()?;
        let p = self.spec.n_params_elems();
        anyhow::ensure!(all.len() == 3 * p, "state length {}", all.len());
        Ok(Tensor::new(vec![p], all[..p].to_vec()))
    }
}
