//! Trainer: drives the `train_step` entry through a [`Session`]. The
//! packed [3P] state round-trips as an opaque `TrainState` per step —
//! the host never unpacks it until checkpointing. This is the in-repo
//! "pretraining" that stands in for the paper's HuggingFace checkpoints
//! (DESIGN.md §1) and the end-to-end driver of `examples/train_prune_eval`.

use crate::data::Dataset;
use crate::model::{zoo, Weights};
use crate::runtime::{Manifest, Session};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::timer::{fmt_duration, Stopwatch};
use anyhow::Result;

pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub wall_s: f64,
}

pub struct TrainOpts {
    pub steps: usize,
    pub lr: f32,
    /// linear warmup steps
    pub warmup: usize,
    pub log_every: usize,
    pub seed: u64,
}

impl TrainOpts {
    pub fn for_model(model: &str) -> TrainOpts {
        let (steps, lr) = zoo::train_budget(model);
        TrainOpts { steps, lr, warmup: 20, log_every: 20, seed: 42 }
    }
}

/// Train from scratch; returns final weights + loss curve.
pub fn train(
    manifest: &Manifest,
    model: &str,
    dataset: &Dataset,
    opts: &TrainOpts,
) -> Result<(Weights, TrainReport)> {
    let session = Session::new(manifest, model)?;
    let spec = session.spec.clone();
    let init = Weights::init(&spec, opts.seed);
    let mut sw = Stopwatch::start();
    let mut state = session.init_train(&init.packed)?;
    sw.split("init");

    let mut losses = Vec::with_capacity(opts.steps);
    for step in 0..opts.steps {
        let batch = dataset.train_batch(step);
        let lr = schedule(opts, step);
        let loss =
            session.train_step(&mut state, &batch.tokens, &batch.targets, (step + 1) as f32, lr)?;
        losses.push(loss);
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            crate::info!(
                "train {model} step {step}/{} loss {loss:.4} lr {lr:.2e} ({})",
                opts.steps,
                fmt_duration(sw.total())
            );
        }
    }
    sw.split("steps");

    let packed = session.train_params(&state)?;
    let mut weights = Weights::zeros(&spec);
    weights.packed = Tensor::new(vec![packed.numel()], packed.data);
    let report = TrainReport {
        losses,
        steps: opts.steps,
        wall_s: sw.total().as_secs_f64(),
    };
    Ok((weights, report))
}

fn schedule(opts: &TrainOpts, step: usize) -> f32 {
    if step < opts.warmup {
        opts.lr * (step + 1) as f32 / opts.warmup as f32
    } else {
        // cosine decay to 10%
        let p = (step - opts.warmup) as f32 / (opts.steps - opts.warmup).max(1) as f32;
        let min = 0.1 * opts.lr;
        min + 0.5 * (opts.lr - min) * (1.0 + (std::f32::consts::PI * p).cos())
    }
}

/// Load the cached checkpoint or train + persist it (plus the loss curve
/// as JSON next to it, for EXPERIMENTS.md).
pub fn ensure_trained(
    manifest: &Manifest,
    model: &str,
    dataset: &Dataset,
) -> Result<Weights> {
    let spec = manifest.model(model)?;
    let path = zoo::checkpoint_path(model);
    if path.exists() {
        match Weights::load(spec, &path) {
            Ok(w) => {
                crate::debug!("loaded checkpoint {}", path.display());
                return Ok(w);
            }
            Err(e) => crate::warn!("checkpoint {} unusable ({e}); retraining", path.display()),
        }
    }
    let opts = TrainOpts::for_model(model);
    crate::info!("no checkpoint for {model}; training {} steps", opts.steps);
    let (weights, report) = train(manifest, model, dataset, &opts)?;
    weights.save(&path)?;
    let curve = Json::obj(vec![
        ("model", Json::Str(model.into())),
        ("steps", Json::Num(report.steps as f64)),
        ("wall_s", Json::Num(report.wall_s)),
        ("losses", Json::arr_f64(&report.losses.iter().map(|&x| x as f64).collect::<Vec<_>>())),
    ]);
    std::fs::write(
        path.with_extension("losses.json"),
        curve.pretty(),
    )?;
    Ok(weights)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_then_decay() {
        let opts = TrainOpts { steps: 100, lr: 1e-3, warmup: 10, log_every: 1000, seed: 0 };
        assert!(schedule(&opts, 0) < 2e-4);
        assert!((schedule(&opts, 9) - 1e-3).abs() < 1e-9);
        assert!(schedule(&opts, 99) < 2.1e-4);
        // monotone decay after warmup
        let mut prev = schedule(&opts, 10);
        for s in 11..100 {
            let cur = schedule(&opts, s);
            assert!(cur <= prev + 1e-12);
            prev = cur;
        }
    }
}
