//! Table 2: perplexity of pruned LLaMA-family models — FASP vs
//! LLM-Pruner / SliceGPT / NASLLM / FLAP across three sizes.

use super::common::{fmt_ppl, ExpCtx};
use crate::bench_support::table::Table;
use crate::model::zoo;
use crate::prune::Method;
use crate::Result;

const METHODS: [Method; 5] = [
    Method::LlmPrunerLike,
    Method::SliceGptLike,
    Method::NasllmAdmm,
    Method::Flap,
    Method::Fasp,
];
const SPARSITIES: [f64; 3] = [0.10, 0.20, 0.30];

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let mut t = Table::new(
        "Table 2 — perplexity (↓) of pruned LLaMA-family models (synthetic-corpus analog)",
        &["Method", "Sparsity", "LLaMA-7B*", "LLaMA-13B*", "LLaMA-30B*"],
    );
    let prepared: Vec<_> = zoo::LLAMA_MODELS
        .iter()
        .map(|m| ctx.prepared(m))
        .collect::<Result<_>>()?;

    let mut dense = vec!["Dense".to_string(), "0%".to_string()];
    for p in &prepared {
        dense.push(fmt_ppl(p.dense_ppl(ctx)?));
    }
    t.row(dense);

    for &s in &SPARSITIES {
        for method in METHODS {
            let mut row = vec![method.label().to_string(), format!("{:.0}%", s * 100.0)];
            for p in &prepared {
                let (ppl, _) = p.prune_and_eval(ctx, method, s)?;
                row.push(fmt_ppl(ppl));
            }
            t.row(row);
        }
    }
    Ok(t.render())
}
