//! Figures 3 & 4: perplexity-vs-sparsity curves. Emits both an ASCII
//! chart and a CSV block so the curves can be replotted externally.

use super::common::ExpCtx;
use crate::bench_support::table::ascii_chart;
use crate::prune::Method;
use crate::Result;
use std::fmt::Write as _;

const SWEEP: [f64; 6] = [0.0, 0.10, 0.20, 0.30, 0.40, 0.50];

fn sweep(ctx: &ExpCtx, model: &str, methods: &[Method], title: &str) -> Result<String> {
    let p = ctx.prepared(model)?;
    let dense = p.dense_ppl(ctx)?;
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    let mut csv = String::from("sparsity");
    for m in methods {
        csv.push(',');
        csv.push_str(m.label());
    }
    csv.push('\n');
    let mut rows: Vec<Vec<f64>> = vec![vec![0.0; methods.len()]; SWEEP.len()];
    for (mi, &method) in methods.iter().enumerate() {
        let mut ys = Vec::with_capacity(SWEEP.len());
        for (si, &s) in SWEEP.iter().enumerate() {
            let ppl = if s == 0.0 {
                dense
            } else {
                p.prune_and_eval(ctx, method, s)?.0
            };
            ys.push(ppl.ln()); // log-scale like the paper's figures
            rows[si][mi] = ppl;
        }
        series.push((method.label().to_string(), ys));
    }
    for (si, &s) in SWEEP.iter().enumerate() {
        let _ = write!(csv, "{:.2}", s);
        for v in &rows[si] {
            let _ = write!(csv, ",{:.4}", v);
        }
        csv.push('\n');
    }
    let mut out = ascii_chart(
        &format!("{title} — log(PPL) vs sparsity, {model}"),
        &SWEEP,
        &series,
        16,
    );
    out.push_str("\n```csv\n");
    out.push_str(&csv);
    out.push_str("```\n");
    Ok(out)
}

pub fn run_fig3(ctx: &ExpCtx) -> Result<String> {
    let methods = [Method::SliceGptLike, Method::NasllmAdmm, Method::Fasp];
    let mut out = String::new();
    for model in ["opt_small", "opt_medium"] {
        out.push_str(&sweep(ctx, model, &methods, "Figure 3")?);
    }
    Ok(out)
}

pub fn run_fig4(ctx: &ExpCtx) -> Result<String> {
    let methods = [
        Method::LlmPrunerLike,
        Method::SliceGptLike,
        Method::NasllmAdmm,
        Method::Flap,
        Method::Fasp,
    ];
    let mut out = String::new();
    for model in ["llama_small", "llama_medium"] {
        out.push_str(&sweep(ctx, model, &methods, "Figure 4")?);
    }
    Ok(out)
}
