//! Table 5 ablation: the pruning *structure*. "Wanda" row = per-operator
//! column pruning with evenly distributed sparsity + optimal update but
//! no coupling; "FASP" row = the coupled structure with Q/K skipped.
//! Paper model: OPT-125M (our `opt_tiny`).

use super::common::{fmt_ppl, ExpCtx};
use crate::bench_support::table::Table;
use crate::prune::Method;
use crate::Result;

const MODEL: &str = "opt_tiny";
const SPARSITIES: [f64; 3] = [0.10, 0.20, 0.30];

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let p = ctx.prepared(MODEL)?;
    let mut t = Table::new(
        "Table 5 — ablation on the pruning structure (perplexity ↓, OPT-125M*)",
        &["", "10%", "20%", "30%"],
    );
    for (label, method) in [("Wanda", Method::WandaStruct), ("FASP", Method::Fasp)] {
        let mut row = vec![label.to_string()];
        for &s in &SPARSITIES {
            let (ppl, _) = p.prune_and_eval(ctx, method, s)?;
            row.push(fmt_ppl(ppl));
        }
        t.row(row);
    }
    Ok(t.render())
}
