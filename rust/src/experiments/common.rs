//! Shared experiment plumbing: per-model preparation (dataset, session,
//! cached trained weights), method runs, and PPL formatting.

use crate::data::{Corpus, Dataset};
use crate::eval::perplexity;
use crate::model::Weights;
use crate::prune::{self, Method, PruneOpts, PruneReport};
use crate::runtime::{Manifest, Session};
use crate::Result;

/// Experiment context: manifest + budget knobs (shrunk by `--fast`).
pub struct ExpCtx {
    pub manifest: Manifest,
    pub eval_batches: usize,
    pub calib_batches: usize,
    pub tasks_per_suite: usize,
    pub seed: u64,
}

impl ExpCtx {
    pub fn new(manifest: Manifest, fast: bool) -> ExpCtx {
        ExpCtx {
            manifest,
            eval_batches: if fast { 4 } else { 12 },
            calib_batches: if fast { 4 } else { 8 },
            tasks_per_suite: if fast { 40 } else { 120 },
            seed: 42,
        }
    }

    /// Session + dataset + trained weights for one zoo model.
    pub fn prepared(&self, model: &str) -> Result<Prepared<'_>> {
        let session = Session::new(&self.manifest, model)?;
        let spec = session.spec.clone();
        let (steps, _) = crate::model::zoo::train_budget(model);
        let corpus = Corpus::new(spec.vocab, self.seed ^ spec.vocab as u64);
        let dataset = Dataset::new(corpus, spec.batch, spec.seq, steps + 8);
        let weights = crate::train::ensure_trained(&self.manifest, model, &dataset)?;
        Ok(Prepared { session, dataset, weights })
    }
}

pub struct Prepared<'m> {
    pub session: Session<'m>,
    pub dataset: Dataset,
    pub weights: Weights,
}

impl<'m> Prepared<'m> {
    pub fn dense_ppl(&self, ctx: &ExpCtx) -> Result<f64> {
        perplexity(
            &self.session,
            &self.weights,
            &self.dataset.valid_batches(ctx.eval_batches),
        )
    }

    /// Prune with `method` at `sparsity`; return (ppl, report).
    pub fn prune_and_eval(
        &self,
        ctx: &ExpCtx,
        method: Method,
        sparsity: f64,
    ) -> Result<(f64, PruneReport)> {
        let (pruned, _mask, report) = self.prune_only(ctx, method, sparsity)?;
        let ppl = perplexity(
            &self.session,
            &pruned,
            &self.dataset.valid_batches(ctx.eval_batches),
        )?;
        crate::info!(
            "{} {} s={:.0}% → ppl {:.2} ({:.2}s)",
            self.session.spec.name,
            method.label(),
            sparsity * 100.0,
            ppl,
            report.total_s
        );
        Ok((ppl, report))
    }

    pub fn prune_only(
        &self,
        ctx: &ExpCtx,
        method: Method,
        sparsity: f64,
    ) -> Result<(Weights, crate::model::PruneMask, PruneReport)> {
        let mut opts = PruneOpts::new(method, sparsity);
        opts.calib_batches = ctx.calib_batches;
        prune::prune(&self.session, &self.weights, &self.dataset, &opts)
    }

    /// Pruned weights with explicit opts (ablations).
    pub fn prune_with(
        &self,
        opts: &PruneOpts,
    ) -> Result<(Weights, crate::model::PruneMask, PruneReport)> {
        prune::prune(&self.session, &self.weights, &self.dataset, opts)
    }

    pub fn ppl_of(&self, ctx: &ExpCtx, w: &Weights) -> Result<f64> {
        perplexity(&self.session, w, &self.dataset.valid_batches(ctx.eval_batches))
    }
}

pub fn fmt_ppl(p: f64) -> String {
    if p > 9999.0 {
        format!("{:.2e}", p)
    } else {
        format!("{:.2}", p)
    }
}
