//! Experiment registry: one module per paper table/figure (DESIGN.md §4).
//! Every experiment regenerates its table/figure from scratch — training
//! checkpoints are cached under `checkpoints/`, outputs land in
//! `results/<id>.md` and on stdout.

pub mod common;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;
pub mod table6;
pub mod fig34;
pub mod extensions;
pub mod quant;

use crate::Result;
use common::ExpCtx;

pub struct Experiment {
    pub id: &'static str,
    pub paper_ref: &'static str,
    pub run: fn(&ExpCtx) -> Result<String>,
}

pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment { id: "table1", paper_ref: "Table 1: OPT perplexity vs sparsity", run: table1::run },
        Experiment { id: "table2", paper_ref: "Table 2: LLaMA perplexity vs sparsity", run: table2::run },
        Experiment { id: "table3", paper_ref: "Table 3: zero-shot accuracy (LLaMA)", run: table3::run },
        Experiment { id: "table4", paper_ref: "Table 4: pruning wall-time", run: table4::run },
        Experiment { id: "table5", paper_ref: "Table 5: pruning-structure ablation", run: table5::run },
        Experiment { id: "table6", paper_ref: "Table 6: Q/K pruning ablation", run: table6::run },
        Experiment { id: "fig3", paper_ref: "Figure 3: PPL-vs-sparsity curves (OPT)", run: fig34::run_fig3 },
        Experiment { id: "fig4", paper_ref: "Figure 4: PPL-vs-sparsity curves (LLaMA)", run: fig34::run_fig4 },
        Experiment { id: "ext_adaptive", paper_ref: "Extension: adaptive per-layer sparsity (§5 future work)", run: extensions::run_adaptive },
        Experiment { id: "ext_admm", paper_ref: "Extension: ADMM-vs-closed-form trade-off (§3.3)", run: extensions::run_admm },
        Experiment { id: "ext_calib", paper_ref: "Extension: calibration-budget sensitivity", run: extensions::run_calib },
        Experiment { id: "quant", paper_ref: "Perf iteration: int8 packed panels, ppl-vs-bytes", run: quant::run },
    ]
}

/// Run one experiment by id (or "all") and persist outputs.
pub fn run_by_id(ctx: &ExpCtx, id: &str) -> Result<()> {
    let reg = registry();
    let selected: Vec<&Experiment> = if id == "all" {
        reg.iter().collect()
    } else {
        reg.iter().filter(|e| e.id == id).collect()
    };
    anyhow::ensure!(
        !selected.is_empty(),
        "unknown experiment '{id}' (have: {}, all)",
        reg.iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
    );
    let dir = crate::repo_root().join("results");
    std::fs::create_dir_all(&dir)?;
    for exp in selected {
        crate::info!("=== {} — {} ===", exp.id, exp.paper_ref);
        let out = (exp.run)(ctx)?;
        println!("{out}");
        std::fs::write(dir.join(format!("{}.md", exp.id)), &out)?;
    }
    Ok(())
}
