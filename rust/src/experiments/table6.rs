//! Table 6 ablation: pruning W_Q/W_K rows (with evenly distributed
//! sparsity) vs FASP's default of skipping them and rebalancing.
//! Paper model: OPT-125M (our `opt_tiny`).

use super::common::{fmt_ppl, ExpCtx};
use crate::bench_support::table::Table;
use crate::prune::{Method, PruneOpts};
use crate::Result;

const MODEL: &str = "opt_tiny";
const SPARSITIES: [f64; 3] = [0.10, 0.20, 0.30];

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let p = ctx.prepared(MODEL)?;
    let mut t = Table::new(
        "Table 6 — ablation on pruning W_Q and W_K (perplexity ↓, OPT-125M*)",
        &["", "10%", "20%", "30%"],
    );
    for (label, prune_qk) in [("Pruning W_Q and W_K", true), ("FASP", false)] {
        let mut row = vec![label.to_string()];
        for &s in &SPARSITIES {
            let mut opts = PruneOpts::new(Method::Fasp, s);
            opts.calib_batches = ctx.calib_batches;
            opts.prune_qk = prune_qk;
            let (w, _, _) = p.prune_with(&opts)?;
            row.push(fmt_ppl(p.ppl_of(ctx, &w)?));
        }
        t.row(row);
    }
    Ok(t.render())
}
