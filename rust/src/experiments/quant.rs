//! `quant` — int8 packed-panel quality/bytes receipt (perf iteration):
//! the same weights evaluated through exact-f32 panels and through the
//! int8 quantized plan, dense and FASP-pruned, on both families. The
//! int8 path is what a deployed quantized plan actually computes
//! (dequant-in-register product kernels), so the ppl delta here is the
//! honest cost of halving (in fact quartering) resident weight bytes.

use super::common::{fmt_ppl, ExpCtx};
use crate::bench_support::table::Table;
use crate::eval::perplexity_as;
use crate::prune::Method;
use crate::tensor::pack::Quant;
use crate::Result;

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let mut out = String::new();
    for model in ["opt_tiny", "llama_tiny"] {
        let p = ctx.prepared(model)?;
        let eval_b = p.dataset.valid_batches(ctx.eval_batches);
        let mut t = Table::new(
            &format!("Int8 packed panels vs f32 — {model} (FASP, PPL ↓)"),
            &["sparsity", "f32 ppl", "int8 ppl", "delta", "f32 pack", "int8 pack"],
        );
        for &s in &[0.0, 0.30, 0.50] {
            let w = if s == 0.0 {
                p.weights.clone()
            } else {
                p.prune_only(ctx, Method::Fasp, s)?.0
            };
            let ppl_f32 = perplexity_as(&p.session, &w, &eval_b, Quant::F32)?;
            let ppl_int8 = perplexity_as(&p.session, &w, &eval_b, Quant::Int8)?;
            let b_f32 = p.session.pack_as(&w.packed, Quant::F32)?.pack_bytes();
            let b_int8 = p.session.pack_as(&w.packed, Quant::Int8)?.pack_bytes();
            crate::info!(
                "{model} s={:.0}%: f32 ppl {:.3} vs int8 {:.3} ({:+.3}), \
                 pack {:.2}MB → {:.2}MB ({:.2}x)",
                s * 100.0,
                ppl_f32,
                ppl_int8,
                ppl_int8 - ppl_f32,
                b_f32 as f64 / 1e6,
                b_int8 as f64 / 1e6,
                b_int8 as f64 / b_f32.max(1) as f64
            );
            t.row(vec![
                format!("{:.0}%", s * 100.0),
                fmt_ppl(ppl_f32),
                fmt_ppl(ppl_int8),
                format!("{:+.3}", ppl_int8 - ppl_f32),
                format!("{:.2}MB", b_f32 as f64 / 1e6),
                format!(
                    "{:.2}MB ({:.2}x)",
                    b_int8 as f64 / 1e6,
                    b_int8 as f64 / b_f32.max(1) as f64
                ),
            ]);
        }
        out.push_str(&t.render());
    }
    Ok(out)
}
