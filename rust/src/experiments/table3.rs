//! Table 3: zero-shot accuracy of the pruned LLaMA-7B analog on the
//! seven synthetic suites at 10% / 20% sparsity.

use super::common::ExpCtx;
use crate::bench_support::table::Table;
use crate::data::tasks::{TaskKind, TaskSuite};
use crate::eval::eval_suite;
use crate::model::Weights;
use crate::prune::Method;
use crate::Result;

const METHODS: [Method; 4] =
    [Method::LlmPrunerLike, Method::SliceGptLike, Method::Flap, Method::Fasp];
const MODEL: &str = "llama_small";

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let p = ctx.prepared(MODEL)?;
    let suites: Vec<TaskSuite> = TaskKind::all()
        .iter()
        .map(|&k| TaskSuite::generate(&p.dataset.corpus, k, ctx.tasks_per_suite, ctx.seed))
        .collect();

    let mut headers: Vec<&str> = vec!["Method", "Sparsity"];
    let labels: Vec<&'static str> = suites.iter().map(|s| s.kind.label()).collect();
    headers.extend(labels.iter());
    headers.push("Mean");
    let mut t = Table::new(
        "Table 3 — zero-shot accuracy (↑, %) of pruned LLaMA-7B* on the synthetic suites",
        &headers,
    );

    let score = |w: &Weights| -> Result<Vec<f64>> {
        let mut accs = Vec::with_capacity(suites.len());
        for s in &suites {
            accs.push(eval_suite(&p.session, w, s)?.accuracy);
        }
        Ok(accs)
    };
    let add_row = |t: &mut Table, name: &str, sp: &str, accs: &[f64]| {
        let mut row = vec![name.to_string(), sp.to_string()];
        for a in accs {
            row.push(format!("{:.2}", a));
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        row.push(format!("{:.2}", mean));
        t.row(row);
    };

    add_row(&mut t, "Dense", "0%", &score(&p.weights)?);
    for &s in &[0.10, 0.20] {
        for method in METHODS {
            let (w, _, _) = p.prune_only(ctx, method, s)?;
            add_row(
                &mut t,
                method.label(),
                &format!("{:.0}%", s * 100.0),
                &score(&w)?,
            );
        }
    }
    Ok(t.render())
}
