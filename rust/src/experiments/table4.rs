//! Table 4: pruning wall-time by method and model size (LLaMA family).
//! The paper's claim is the cost *ordering* — FASP ≈ FLAP ≪ SliceGPT ≪
//! NASLLM/LLM-Pruner — which this regenerates on the shared substrate,
//! including the per-phase breakdown that explains it.

use super::common::ExpCtx;
use crate::bench_support::table::Table;
use crate::model::zoo;
use crate::prune::Method;
use crate::util::timer::fmt_duration;
use crate::Result;
use std::time::Duration;

const METHODS: [Method; 5] = [
    Method::NasllmAdmm,
    Method::LlmPrunerLike,
    Method::SliceGptLike,
    Method::Flap,
    Method::Fasp,
];

pub fn run(ctx: &ExpCtx) -> Result<String> {
    let mut t = Table::new(
        "Table 4 — pruning wall-time at 20% sparsity (lower is better)",
        &["Method", "LLaMA-7B*", "LLaMA-13B*", "LLaMA-30B*", "phase breakdown (30B*)"],
    );
    let prepared: Vec<_> = zoo::LLAMA_MODELS
        .iter()
        .map(|m| ctx.prepared(m))
        .collect::<Result<_>>()?;

    for method in METHODS {
        let mut row = vec![method.label().to_string()];
        let mut last_phases = String::new();
        for p in &prepared {
            let (_, report) = p.prune_and_eval(ctx, method, 0.20)?;
            row.push(fmt_duration(Duration::from_secs_f64(report.total_s)));
            last_phases = report
                .phase_s
                .iter()
                .map(|(n, s)| format!("{n} {:.2}s", s))
                .collect::<Vec<_>>()
                .join(", ");
        }
        row.push(last_phases);
        t.row(row);
    }
    Ok(t.render())
}
