//! Beyond-paper extension experiments (DESIGN.md process step 5):
//!
//! * `ext_adaptive` — the paper's §5 future-work idea: adaptive per-layer
//!   sparsity (global z-scored selection) vs the default uniform ratios.
//! * `ext_admm` — the §3.3 efficiency/accuracy argument quantified: ADMM
//!   iteration count vs wall-time vs resulting perplexity, against the
//!   closed-form restoration.
//! * `ext_calib` — calibration-budget sensitivity of FASP (the paper
//!   fixes 128 samples; how robust is the method to fewer?).

use super::common::{fmt_ppl, ExpCtx};
use crate::bench_support::table::Table;
use crate::prune::{Method, PruneOpts};
use crate::Result;

const MODEL: &str = "llama_tiny";

pub fn run_adaptive(ctx: &ExpCtx) -> Result<String> {
    let mut out = String::new();
    for model in ["opt_tiny", "llama_tiny"] {
        let p = ctx.prepared(model)?;
        let mut t = Table::new(
            &format!("Extension — adaptive per-layer sparsity ({model}, PPL ↓)"),
            &["", "20%", "30%", "40%"],
        );
        for (label, adaptive) in [("FASP uniform", false), ("FASP adaptive", true)] {
            let mut row = vec![label.to_string()];
            for &s in &[0.20, 0.30, 0.40] {
                let mut opts = PruneOpts::new(Method::Fasp, s);
                opts.calib_batches = ctx.calib_batches;
                opts.adaptive = adaptive;
                let (w, _, _) = p.prune_with(&opts)?;
                row.push(fmt_ppl(p.ppl_of(ctx, &w)?));
            }
            t.row(row);
        }
        out.push_str(&t.render());
    }
    Ok(out)
}

pub fn run_admm(ctx: &ExpCtx) -> Result<String> {
    let p = ctx.prepared(MODEL)?;
    let mut t = Table::new(
        "Extension — restoration solver trade-off at 30% sparsity (llama_tiny)",
        &["restorer", "PPL ↓", "restore time", "total time"],
    );
    // closed form (FASP)
    {
        let mut opts = PruneOpts::new(Method::Fasp, 0.30);
        opts.calib_batches = ctx.calib_batches;
        let (w, _, rep) = p.prune_with(&opts)?;
        t.row(vec![
            "closed form (Eq. 8)".into(),
            fmt_ppl(p.ppl_of(ctx, &w)?),
            format!("{:.3}s", rep.phase("restore")),
            format!("{:.2}s", rep.total_s),
        ]);
    }
    for iters in [2usize, 8, 32, 128] {
        let mut opts = PruneOpts::new(Method::NasllmAdmm, 0.30);
        opts.calib_batches = ctx.calib_batches;
        opts.admm_iters = iters;
        let (w, _, rep) = p.prune_with(&opts)?;
        t.row(vec![
            format!("ADMM {iters} iters"),
            fmt_ppl(p.ppl_of(ctx, &w)?),
            format!("{:.3}s", rep.phase("restore")),
            format!("{:.2}s", rep.total_s),
        ]);
    }
    Ok(t.render())
}

pub fn run_calib(ctx: &ExpCtx) -> Result<String> {
    let p = ctx.prepared(MODEL)?;
    let mut t = Table::new(
        "Extension — calibration-budget sensitivity, FASP 30% (llama_tiny)",
        &["calib batches (×B×T rows)", "PPL ↓", "capture time"],
    );
    for &n in &[1usize, 2, 4, 8, 16] {
        let mut opts = PruneOpts::new(Method::Fasp, 0.30);
        opts.calib_batches = n;
        let (w, _, rep) = p.prune_with(&opts)?;
        t.row(vec![
            n.to_string(),
            fmt_ppl(p.ppl_of(ctx, &w)?),
            format!("{:.2}s", rep.phase("capture")),
        ]);
    }
    Ok(t.render())
}
