//! Seven synthetic zero-shot suites — the BoolQ/PIQA/HellaSwag/
//! WinoGrande/ARC-e/ARC-c/OBQA substitution (Table 3).
//!
//! Each task is likelihood ranking, exactly like lm-eval-harness: a
//! prompt sampled from the corpus, `n_choices` candidate continuations
//! (one drawn from the generator's grammar, distractors per task kind),
//! scored by the summed NLL of the candidate span given the prompt.
//! Ground truth comes from the generator itself, so accuracy measures how
//! much of the learned grammar survives pruning — the same signal the
//! paper's zero-shot tables carry.

use super::corpus::Corpus;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// 2-way true-vs-shuffled continuation (yes/no flavor).
    BoolQS,
    /// 2-way short continuation ranking.
    PiqaS,
    /// 4-way long continuation ranking.
    HellaSwagS,
    /// 2-way single-token cloze.
    WinograndeS,
    /// 4-way, distractors far from the grammar (easy margin).
    ArcES,
    /// 4-way, distractors drawn from the state's own successor set (hard).
    ArcCS,
    /// 4-way short continuation.
    ObqaS,
}

impl TaskKind {
    pub fn all() -> [TaskKind; 7] {
        [
            TaskKind::BoolQS,
            TaskKind::PiqaS,
            TaskKind::HellaSwagS,
            TaskKind::WinograndeS,
            TaskKind::ArcES,
            TaskKind::ArcCS,
            TaskKind::ObqaS,
        ]
    }

    /// Column label used in Table 3 output.
    pub fn label(&self) -> &'static str {
        match self {
            TaskKind::BoolQS => "BoolQ-s",
            TaskKind::PiqaS => "PIQA-s",
            TaskKind::HellaSwagS => "HellaSwag-s",
            TaskKind::WinograndeS => "WinoGrande-s",
            TaskKind::ArcES => "ARC-e-s",
            TaskKind::ArcCS => "ARC-c-s",
            TaskKind::ObqaS => "OBQA-s",
        }
    }

    pub fn n_choices(&self) -> usize {
        match self {
            TaskKind::BoolQS | TaskKind::PiqaS | TaskKind::WinograndeS => 2,
            _ => 4,
        }
    }

    pub fn cont_len(&self) -> usize {
        match self {
            TaskKind::WinograndeS => 1,
            TaskKind::ObqaS => 4,
            TaskKind::PiqaS | TaskKind::BoolQS => 8,
            TaskKind::ArcES | TaskKind::ArcCS => 6,
            TaskKind::HellaSwagS => 12,
        }
    }
}

/// One ranking instance.
#[derive(Clone, Debug)]
pub struct Task {
    pub prompt: Vec<i32>,
    /// candidate continuations, all the same length.
    pub choices: Vec<Vec<i32>>,
    pub answer: usize,
}

/// A generated suite of tasks of one kind.
pub struct TaskSuite {
    pub kind: TaskKind,
    pub tasks: Vec<Task>,
}

impl TaskSuite {
    pub fn generate(corpus: &Corpus, kind: TaskKind, n: usize, seed: u64) -> TaskSuite {
        let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0xABCD_EF12));
        let mut tasks = Vec::with_capacity(n);
        let prompt_len = 16;
        while tasks.len() < n {
            let prompt = corpus.generate(prompt_len, &mut rng);
            if let Some(t) = make_task(corpus, kind, &prompt, &mut rng) {
                tasks.push(t);
            }
        }
        TaskSuite { kind, tasks }
    }
}

fn make_task(corpus: &Corpus, kind: TaskKind, prompt: &[i32], rng: &mut Rng) -> Option<Task> {
    let len = kind.cont_len();
    let truth = corpus.greedy_continuation(prompt, len);
    let n_choices = kind.n_choices();
    let mut choices = Vec::with_capacity(n_choices);
    choices.push(truth.clone());
    for _ in 1..n_choices {
        let d = distractor(corpus, kind, prompt, &truth, rng);
        choices.push(d);
    }
    // all-same choices make the task degenerate — skip
    if choices[1..].iter().any(|c| *c == choices[0]) {
        return None;
    }
    // shuffle: answer position uniform
    let answer_pos = rng.below(n_choices);
    choices.swap(0, answer_pos);
    Some(Task { prompt: prompt.to_vec(), choices, answer: answer_pos })
}

fn distractor(
    corpus: &Corpus,
    kind: TaskKind,
    prompt: &[i32],
    truth: &[i32],
    rng: &mut Rng,
) -> Vec<i32> {
    let len = truth.len();
    match kind {
        // shuffled copy of the true continuation (order destroyed)
        TaskKind::BoolQS => {
            let mut d = truth.to_vec();
            for _ in 0..8 {
                rng.shuffle(&mut d);
                if d != truth {
                    break;
                }
            }
            d
        }
        // continuation from an unrelated random state
        TaskKind::PiqaS | TaskKind::HellaSwagS | TaskKind::ObqaS => {
            let fake_prefix = [rng.below(corpus.vocab) as i32, rng.below(corpus.vocab) as i32];
            corpus.greedy_continuation(&fake_prefix, len)
        }
        // cloze: a different token at the blank
        TaskKind::WinograndeS => {
            let mut tok = rng.below(corpus.vocab) as i32;
            while tok == truth[0] {
                tok = rng.below(corpus.vocab) as i32;
            }
            vec![tok]
        }
        // easy: uniform random tokens (far off-grammar)
        TaskKind::ArcES => (0..len).map(|_| rng.below(corpus.vocab) as i32).collect(),
        // hard: walk the grammar but start from a *non-modal* successor
        TaskKind::ArcCS => {
            let (a, b) = (prompt[prompt.len() - 2], prompt[prompt.len() - 1]);
            let succ = corpus.successors(a, b);
            let alt = succ[1 + rng.below(succ.len() - 1)];
            let mut d = vec![alt];
            let mut pre = vec![b, alt];
            d.extend(corpus.greedy_continuation(&pre.split_off(0), len - 1));
            d.truncate(len);
            d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_generate() {
        let corpus = Corpus::new(256, 11);
        for kind in TaskKind::all() {
            let suite = TaskSuite::generate(&corpus, kind, 20, 5);
            assert_eq!(suite.tasks.len(), 20);
            for t in &suite.tasks {
                assert_eq!(t.choices.len(), kind.n_choices());
                assert!(t.answer < t.choices.len());
                let len = t.choices[0].len();
                assert!(t.choices.iter().all(|c| c.len() == len));
                // the answer differs from every distractor
                for (i, c) in t.choices.iter().enumerate() {
                    if i != t.answer {
                        assert_ne!(*c, t.choices[t.answer]);
                    }
                }
            }
        }
    }

    #[test]
    fn deterministic() {
        let corpus = Corpus::new(256, 11);
        let a = TaskSuite::generate(&corpus, TaskKind::PiqaS, 5, 1);
        let b = TaskSuite::generate(&corpus, TaskKind::PiqaS, 5, 1);
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }
}
