//! Synthetic data substrate — the WikiText-2 / zero-shot-benchmark
//! substitution (DESIGN.md §1). A seeded order-2 Markov–Zipf generator
//! produces a corpus with learnable structure; calibration sets,
//! perplexity splits and the seven zero-shot suites are all derived from
//! it deterministically.

pub mod corpus;
pub mod dataset;
pub mod tasks;

pub use corpus::Corpus;
pub use dataset::{Batch, Dataset};
pub use tasks::{Task, TaskKind, TaskSuite};
