//! Dataset plumbing: deterministic train/valid/calibration splits over
//! the synthetic corpus, chunked into fixed [B, T] batches with
//! shifted-by-one targets (teacher forcing) — the same protocol the paper
//! uses with WikiText-2 (128 calibration samples, 2048 ctx; ours is
//! B×T-shaped by the artifact's static shapes).

use super::corpus::Corpus;
use crate::tensor::IntTensor;
use crate::util::rng::Rng;

/// A [B, T] token batch with next-token targets.
#[derive(Clone)]
pub struct Batch {
    pub tokens: IntTensor,
    pub targets: IntTensor,
}

pub struct Dataset {
    pub corpus: Corpus,
    pub batch: usize,
    pub seq: usize,
    train_stream: Vec<i32>,
    valid_stream: Vec<i32>,
    calib_stream: Vec<i32>,
}

impl Dataset {
    /// Materialize streams sized for `train_batches` of training plus
    /// fixed validation/calibration pools. Distinct RNG streams per split
    /// keep splits disjoint in distribution (different sample paths).
    pub fn new(corpus: Corpus, batch: usize, seq: usize, train_batches: usize) -> Dataset {
        let mut rng = Rng::new(corpus.seed ^ 0xDA7A);
        let span = batch * (seq + 1);
        let train_stream = corpus.generate(span * train_batches.max(1), &mut rng.fork(1));
        let valid_stream = corpus.generate(span * 64, &mut rng.fork(2));
        let calib_stream = corpus.generate(span * 32, &mut rng.fork(3));
        Dataset { corpus, batch, seq, train_stream, valid_stream, calib_stream }
    }

    fn cut(&self, stream: &[i32], idx: usize) -> Batch {
        let span = self.batch * (self.seq + 1);
        let start = (idx * span) % (stream.len() - span + 1);
        let window = &stream[start..start + span];
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        for b in 0..self.batch {
            let row = &window[b * (self.seq + 1)..(b + 1) * (self.seq + 1)];
            tokens.extend_from_slice(&row[..self.seq]);
            targets.extend_from_slice(&row[1..]);
        }
        Batch {
            tokens: IntTensor::new(vec![self.batch, self.seq], tokens),
            targets: IntTensor::new(vec![self.batch, self.seq], targets),
        }
    }

    /// i-th training batch (wraps around the stream).
    pub fn train_batch(&self, i: usize) -> Batch {
        self.cut(&self.train_stream, i)
    }

    /// Held-out perplexity batches.
    pub fn valid_batches(&self, n: usize) -> Vec<Batch> {
        (0..n).map(|i| self.cut(&self.valid_stream, i)).collect()
    }

    /// Calibration batches (the paper's "128 random samples" analog:
    /// n_batches × B sequences).
    pub fn calib_batches(&self, n: usize) -> Vec<Batch> {
        (0..n).map(|i| self.cut(&self.calib_stream, i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn targets_are_shifted_tokens() {
        let ds = Dataset::new(Corpus::new(64, 9), 2, 8, 4);
        let b = ds.train_batch(0);
        assert_eq!(b.tokens.shape, vec![2, 8]);
        // target[i] should equal token[i+1] within each row
        for row in 0..2 {
            for i in 0..7 {
                assert_eq!(
                    b.targets.data[row * 8 + i],
                    b.tokens.data[row * 8 + i + 1]
                );
            }
        }
    }

    #[test]
    fn deterministic_batches() {
        let ds1 = Dataset::new(Corpus::new(64, 9), 2, 8, 4);
        let ds2 = Dataset::new(Corpus::new(64, 9), 2, 8, 4);
        assert_eq!(ds1.train_batch(3).tokens.data, ds2.train_batch(3).tokens.data);
        assert_ne!(
            ds1.train_batch(0).tokens.data,
            ds1.valid_batches(1)[0].tokens.data
        );
    }
}
