//! Markov chain with Zipfian marginals — the synthetic stand-in for
//! WikiText-2.
//!
//! Construction (all deterministic from `seed`):
//! * each token `b` hashes to `K` preferred successors with fixed
//!   mixture weights (0.45/0.25/0.18/0.12) — the "grammar" (order-1 so a
//!   sub-1M-parameter model can actually learn it: V·K associations, not
//!   V²·K — the original order-2 variant was pure memorization and
//!   trained ~30× slower for the same PPL drop);
//! * with probability `NOISE` the next token is drawn from a global
//!   Zipf(1.1) unigram instead — the "noise floor";
//! * the entropy rate sits well below `log V`, so a trained model's PPL
//!   is meaningfully lower than random and pruning damage is measurable.
//!
//! The generator doubles as ground truth for the zero-shot suites: the
//! preferred-successor table says which continuation is "correct". The
//! `(a, b)` state signature is kept so task code stays order-agnostic.

use crate::util::rng::Rng;

pub const SUCCESSORS: usize = 4;
pub const SUCC_WEIGHTS: [f64; SUCCESSORS] = [0.45, 0.25, 0.18, 0.12];
pub const NOISE: f64 = 0.15;

#[derive(Clone)]
pub struct Corpus {
    pub vocab: usize,
    pub seed: u64,
    /// Zipf unigram weights (unnormalized).
    zipf: Vec<f64>,
}

impl Corpus {
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        Corpus { vocab, seed, zipf: Rng::zipf_weights(vocab, 1.1) }
    }

    /// The K preferred successors of state (a, b) — a deterministic hash
    /// of the current token `b` and the corpus seed (`a` is ignored;
    /// order-1 grammar, see module docs).
    pub fn successors(&self, _a: i32, b: i32) -> [i32; SUCCESSORS] {
        let mut out = [0i32; SUCCESSORS];
        let mut h = self
            .seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(b as u64 & 0xffff_ffff);
        for slot in out.iter_mut() {
            // splitmix-style scramble per slot
            h = h.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = h;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^= z >> 31;
            *slot = (z % self.vocab as u64) as i32;
        }
        out
    }

    /// Sample the next token given state (a, b).
    pub fn next_token(&self, a: i32, b: i32, rng: &mut Rng) -> i32 {
        if rng.f64() < NOISE {
            rng.categorical(&self.zipf) as i32
        } else {
            let succ = self.successors(a, b);
            succ[rng.categorical(&SUCC_WEIGHTS)]
        }
    }

    /// The generator's modal continuation (the "correct answer" for
    /// zero-shot ground truth).
    pub fn best_successor(&self, a: i32, b: i32) -> i32 {
        self.successors(a, b)[0]
    }

    /// Generate `n` tokens starting from a random state.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        let mut a = rng.below(self.vocab) as i32;
        let mut b = rng.below(self.vocab) as i32;
        for _ in 0..n {
            let c = self.next_token(a, b, rng);
            out.push(c);
            a = b;
            b = c;
        }
        out
    }

    /// Continue a given prefix for `n` more tokens.
    pub fn continue_from(&self, prefix: &[i32], n: usize, rng: &mut Rng) -> Vec<i32> {
        assert!(prefix.len() >= 2);
        let mut a = prefix[prefix.len() - 2];
        let mut b = prefix[prefix.len() - 1];
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let c = self.next_token(a, b, rng);
            out.push(c);
            a = b;
            b = c;
        }
        out
    }

    /// Greedy (modal) continuation — used as the "true" answer span.
    pub fn greedy_continuation(&self, prefix: &[i32], n: usize) -> Vec<i32> {
        let mut a = prefix[prefix.len() - 2];
        let mut b = prefix[prefix.len() - 1];
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let c = self.best_successor(a, b);
            out.push(c);
            a = b;
            b = c;
        }
        out
    }

    /// Theoretical cross-entropy upper bound of the chain in nats (the
    /// mixture's entropy if the model learned the grammar exactly);
    /// useful to sanity-check training progress.
    pub fn entropy_bound(&self) -> f64 {
        // entropy of the successor mixture + noise smeared over Zipf
        let hs: f64 = SUCC_WEIGHTS.iter().map(|w| -w * w.ln()).sum();
        let zsum: f64 = self.zipf.iter().sum();
        let hz: f64 = self
            .zipf
            .iter()
            .map(|w| {
                let p = w / zsum;
                -p * p.ln()
            })
            .sum();
        (1.0 - NOISE) * hs + NOISE * hz
            + binary_entropy(NOISE)
    }
}

fn binary_entropy(p: f64) -> f64 {
    if p <= 0.0 || p >= 1.0 {
        0.0
    } else {
        -p * p.ln() - (1.0 - p) * (1.0 - p).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_successors() {
        let c = Corpus::new(256, 7);
        assert_eq!(c.successors(3, 5), c.successors(3, 5));
        // different states should (almost surely) differ
        assert_ne!(c.successors(3, 5), c.successors(5, 3));
    }

    #[test]
    fn tokens_in_range() {
        let c = Corpus::new(128, 1);
        let mut rng = Rng::new(2);
        for tok in c.generate(5000, &mut rng) {
            assert!((0..128).contains(&tok));
        }
    }

    #[test]
    fn grammar_dominates() {
        // ~85% of transitions should land on a preferred successor
        let c = Corpus::new(256, 3);
        let mut rng = Rng::new(4);
        let toks = c.generate(20_000, &mut rng);
        let mut hits = 0usize;
        for w in toks.windows(3) {
            if c.successors(w[0], w[1]).contains(&w[2]) {
                hits += 1;
            }
        }
        let frac = hits as f64 / (toks.len() - 2) as f64;
        assert!(frac > 0.8, "grammar fraction {frac}");
    }

    #[test]
    fn entropy_below_uniform() {
        let c = Corpus::new(256, 5);
        assert!(c.entropy_bound() < (256f64).ln());
    }
}
