//! Quickstart: the one-screen FASP workflow.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads (or trains on first run) the tiny LLaMA-style model, prunes 20%
//! with FASP, and prints dense-vs-pruned perplexity plus the pruning-time
//! breakdown — the paper's headline workflow end to end.

use fasp::data::{Corpus, Dataset};
use fasp::eval::perplexity;
use fasp::prune::{prune, Method, PruneOpts};
use fasp::runtime::{Manifest, Session};

fn main() -> fasp::Result<()> {
    let model = "llama_tiny";
    let manifest = Manifest::load(&fasp::artifacts_dir())?;
    let session = Session::new(&manifest, model)?;
    let spec = session.spec.clone();
    println!(
        "model {model}: {} layers, d={}, {} params",
        spec.n_layers,
        spec.d_model,
        spec.n_params_elems()
    );

    // dataset + cached checkpoint (trains ~1 min on first run)
    let corpus = Corpus::new(spec.vocab, 42 ^ spec.vocab as u64);
    let dataset = Dataset::new(corpus, spec.batch, spec.seq, 300);
    let weights = fasp::train::ensure_trained(&manifest, model, &dataset)?;

    let eval = dataset.valid_batches(8);
    let dense_ppl = perplexity(&session, &weights, &eval)?;
    println!("dense perplexity: {dense_ppl:.3}");

    // FASP at 20% sparsity
    let opts = PruneOpts::new(Method::Fasp, 0.20);
    let (pruned, mask, report) = prune(&session, &weights, &dataset, &opts)?;
    let pruned_ppl = perplexity(&session, &pruned, &eval)?;

    println!(
        "FASP 20%: achieved sparsity {:.1}% ({} params removed)",
        report.achieved_sparsity * 100.0,
        report.params_removed
    );
    println!("pruned perplexity: {pruned_ppl:.3} (dense {dense_ppl:.3})");
    println!(
        "pruning time {:.2}s — {}",
        report.total_s,
        report
            .phase_s
            .iter()
            .map(|(n, s)| format!("{n} {s:.2}s"))
            .collect::<Vec<_>>()
            .join(", ")
    );
    mask.validate(&spec)?;
    Ok(())
}
