//! Tables 5 & 6 style ablations on one model: pruning structure
//! (coupled FASP vs per-operator Wanda) and Q/K pruning vs skipping.
//!
//! ```bash
//! cargo run --release --example ablations [-- model]
//! ```

use fasp::bench_support::table::Table;
use fasp::experiments::common::{fmt_ppl, ExpCtx};
use fasp::prune::{Method, PruneOpts};
use fasp::runtime::Manifest;

fn main() -> fasp::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "opt_tiny".into());
    let manifest = Manifest::load(&fasp::artifacts_dir())?;
    let ctx = ExpCtx::new(manifest, false);
    let p = ctx.prepared(&model)?;
    let sparsities = [0.10, 0.20, 0.30];

    let mut t5 = Table::new(
        &format!("Ablation: pruning structure ({model})"),
        &["", "10%", "20%", "30%"],
    );
    for (label, method) in [("Wanda (uncoupled)", Method::WandaStruct), ("FASP", Method::Fasp)] {
        let mut row = vec![label.to_string()];
        for &s in &sparsities {
            row.push(fmt_ppl(p.prune_and_eval(&ctx, method, s)?.0));
        }
        t5.row(row);
    }
    t5.print();

    let mut t6 = Table::new(
        &format!("Ablation: pruning W_Q/W_K ({model})"),
        &["", "10%", "20%", "30%"],
    );
    for (label, prune_qk) in [("Pruning W_Q and W_K", true), ("FASP (skip Q/K)", false)] {
        let mut row = vec![label.to_string()];
        for &s in &sparsities {
            let mut opts = PruneOpts::new(Method::Fasp, s);
            opts.calib_batches = ctx.calib_batches;
            opts.prune_qk = prune_qk;
            let (w, _, _) = p.prune_with(&opts)?;
            row.push(fmt_ppl(p.ppl_of(&ctx, &w)?));
        }
        t6.row(row);
    }
    t6.print();

    // bonus: restoration on/off — the §3.3 mechanism in isolation
    let mut t7 = Table::new(
        &format!("Ablation: restoration ({model})"),
        &["", "10%", "20%", "30%"],
    );
    for (label, restore) in [("FASP w/o restoration", false), ("FASP", true)] {
        let mut row = vec![label.to_string()];
        for &s in &sparsities {
            let mut opts = PruneOpts::new(Method::Fasp, s);
            opts.calib_batches = ctx.calib_batches;
            opts.restore = restore;
            let (w, _, _) = p.prune_with(&opts)?;
            row.push(fmt_ppl(p.ppl_of(&ctx, &w)?));
        }
        t7.row(row);
    }
    t7.print();
    Ok(())
}
