//! Table 4 style pruning-time comparison across methods and model sizes,
//! with the per-phase breakdown that explains the ordering.
//!
//! ```bash
//! cargo run --release --example prune_time [-- fast]
//! ```

use fasp::bench_support::table::Table;
use fasp::experiments::common::ExpCtx;
use fasp::model::zoo;
use fasp::prune::Method;
use fasp::runtime::Manifest;

fn main() -> fasp::Result<()> {
    let fast = std::env::args().any(|a| a == "fast");
    let manifest = Manifest::load(&fasp::artifacts_dir())?;
    let ctx = ExpCtx::new(manifest, fast);
    let models: &[&str] = if fast {
        &["llama_tiny"]
    } else {
        &zoo::LLAMA_MODELS
    };

    let mut t = Table::new(
        "Pruning wall-time at 20% sparsity",
        &["Method", "model", "total", "capture", "metric", "restore", "other"],
    );
    for model in models {
        let p = ctx.prepared(model)?;
        for method in Method::all() {
            let (_, rep) = p.prune_and_eval(&ctx, method, 0.20)?;
            let known = rep.phase("capture") + rep.phase("metric") + rep.phase("restore");
            t.row(vec![
                method.label().to_string(),
                model.to_string(),
                format!("{:.2}s", rep.total_s),
                format!("{:.2}s", rep.phase("capture")),
                format!("{:.2}s", rep.phase("metric") + rep.phase("gradcol")),
                format!("{:.2}s", rep.phase("restore") + rep.phase("pca")),
                format!("{:.2}s", (rep.total_s - known).max(0.0)),
            ]);
        }
    }
    t.print();
    Ok(())
}
