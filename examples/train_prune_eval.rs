//! END-TO-END driver (DESIGN.md §4): proves all layers compose on a real
//! small workload.
//!
//! 1. trains the LLaMA-style `small` model for a few hundred steps on the
//!    synthetic corpus **through the AOT `train_step` artifact** (L1
//!    Pallas kernels → L2 JAX graph → PJRT runtime → L3 trainer), logging
//!    the loss curve;
//! 2. prunes the trained model at 20% with FASP and every baseline;
//! 3. evaluates perplexity and the seven zero-shot suites for each;
//! 4. prints the comparison and writes `results/e2e.md`.
//!
//! Recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_prune_eval
//! ```

use fasp::bench_support::table::Table;
use fasp::data::tasks::{TaskKind, TaskSuite};
use fasp::data::{Corpus, Dataset};
use fasp::eval::{eval_suite, perplexity};
use fasp::model::zoo;
use fasp::prune::{prune, Method, PruneOpts};
use fasp::runtime::{Manifest, Session};
use fasp::train::{train, TrainOpts};

fn main() -> fasp::Result<()> {
    let model = "llama_small";
    let manifest = Manifest::load(&fasp::artifacts_dir())?;
    let session = Session::new(&manifest, model)?;
    let spec = session.spec.clone();

    // ---- 1. train through the PJRT train_step artifact -----------------
    let mut opts = TrainOpts::for_model(model);
    if std::env::var("FASP_E2E_FAST").is_ok() {
        opts.steps = 60;
    }
    let corpus = Corpus::new(spec.vocab, 42 ^ spec.vocab as u64);
    let dataset = Dataset::new(corpus, spec.batch, spec.seq, opts.steps + 8);
    println!(
        "training {model} ({} params) for {} steps on the synthetic corpus…",
        spec.n_params_elems(),
        opts.steps
    );
    let (weights, report) = train(&manifest, model, &dataset, &opts)?;
    weights.save(&zoo::checkpoint_path(model))?;
    println!(
        "loss curve: start {:.3} → mid {:.3} → final {:.3}  ({:.1}s total, {:.2}s/step)",
        report.losses.first().unwrap(),
        report.losses[report.losses.len() / 2],
        report.losses.last().unwrap(),
        report.wall_s,
        report.wall_s / report.steps as f64
    );
    // compact curve printout (every ~10%)
    let stride = (report.losses.len() / 10).max(1);
    let curve: Vec<String> = report
        .losses
        .iter()
        .step_by(stride)
        .map(|l| format!("{l:.2}"))
        .collect();
    println!("curve: {}", curve.join(" → "));

    // ---- 2+3. prune with every method, evaluate -------------------------
    let eval_batches = dataset.valid_batches(10);
    let dense_ppl = perplexity(&session, &weights, &eval_batches)?;
    let suites: Vec<TaskSuite> = TaskKind::all()
        .iter()
        .map(|&k| TaskSuite::generate(&dataset.corpus, k, 80, 42))
        .collect();
    let zs = |w: &fasp::model::Weights| -> fasp::Result<f64> {
        let mut acc = 0.0;
        for s in &suites {
            acc += eval_suite(&session, w, s)?.accuracy;
        }
        Ok(acc / suites.len() as f64)
    };

    let mut t = Table::new(
        "End-to-end: train → prune (20%) → evaluate, llama_small",
        &["Method", "PPL ↓", "zero-shot mean ↑", "prune time", "achieved sparsity"],
    );
    t.row(vec![
        "Dense".into(),
        format!("{dense_ppl:.3}"),
        format!("{:.2}%", zs(&weights)?),
        "—".into(),
        "0%".into(),
    ]);
    for method in Method::all() {
        let mut popts = PruneOpts::new(method, 0.20);
        popts.calib_batches = 6;
        let (pw, _, rep) = prune(&session, &weights, &dataset, &popts)?;
        let ppl = perplexity(&session, &pw, &eval_batches)?;
        t.row(vec![
            method.label().to_string(),
            format!("{ppl:.3}"),
            format!("{:.2}%", zs(&pw)?),
            format!("{:.2}s", rep.total_s),
            format!("{:.1}%", rep.achieved_sparsity * 100.0),
        ]);
        println!("{} done ({:.2}s)", method.label(), rep.total_s);
    }
    let rendered = t.render();
    println!("{rendered}");
    let out = fasp::repo_root().join("results");
    std::fs::create_dir_all(&out)?;
    std::fs::write(out.join("e2e.md"), rendered)?;
    println!("written to results/e2e.md");
    Ok(())
}
