//! Table 3 style zero-shot comparison on one model: dense vs FASP vs a
//! baseline, all seven suites.
//!
//! ```bash
//! cargo run --release --example zero_shot [-- model]
//! ```

use fasp::bench_support::table::Table;
use fasp::data::tasks::{TaskKind, TaskSuite};
use fasp::eval::eval_suite;
use fasp::experiments::common::ExpCtx;
use fasp::prune::Method;
use fasp::runtime::Manifest;

fn main() -> fasp::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "llama_tiny".into());
    let manifest = Manifest::load(&fasp::artifacts_dir())?;
    let ctx = ExpCtx::new(manifest, false);
    let p = ctx.prepared(&model)?;

    let suites: Vec<TaskSuite> = TaskKind::all()
        .iter()
        .map(|&k| TaskSuite::generate(&p.dataset.corpus, k, ctx.tasks_per_suite, ctx.seed))
        .collect();

    let mut headers = vec!["Model"];
    let labels: Vec<&'static str> = suites.iter().map(|s| s.kind.label()).collect();
    headers.extend(labels.iter());
    headers.push("Mean");
    let mut t = Table::new(&format!("Zero-shot accuracy (%) — {model}"), &headers);

    let mut add = |name: &str, w: &fasp::model::Weights| -> fasp::Result<()> {
        let mut row = vec![name.to_string()];
        let mut sum = 0.0;
        for s in &suites {
            let r = eval_suite(&p.session, w, s)?;
            sum += r.accuracy;
            row.push(format!("{:.1}", r.accuracy));
        }
        row.push(format!("{:.1}", sum / suites.len() as f64));
        t.row(row);
        Ok(())
    };

    add("Dense", &p.weights)?;
    for (label, method) in
        [("FASP 20%", Method::Fasp), ("FLAP 20%", Method::Flap), ("Magnitude 20%", Method::Magnitude)]
    {
        let (w, _, _) = p.prune_only(&ctx, method, 0.20)?;
        add(label, &w)?;
    }
    t.print();
    Ok(())
}
