//! Figures 3–4 style sweep: perplexity vs sparsity for several methods on
//! one model, printed as ASCII chart + CSV.
//!
//! ```bash
//! cargo run --release --example sparsity_sweep [-- model [fast]]
//! ```

use fasp::experiments::common::ExpCtx;
use fasp::bench_support::table::ascii_chart;
use fasp::prune::Method;
use fasp::runtime::Manifest;

fn main() -> fasp::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("llama_tiny");
    let fast = args.iter().any(|a| a == "fast");

    let manifest = Manifest::load(&fasp::artifacts_dir())?;
    let ctx = ExpCtx::new(manifest, fast);
    let p = ctx.prepared(model)?;

    let sweep = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5];
    let methods = [Method::Magnitude, Method::Flap, Method::Fasp];
    let dense = p.dense_ppl(&ctx)?;

    let mut series = Vec::new();
    println!("sparsity,{}", methods.map(|m| m.label()).join(","));
    let mut rows = vec![vec![0.0f64; methods.len()]; sweep.len()];
    for (mi, &method) in methods.iter().enumerate() {
        let mut ys = Vec::new();
        for (si, &s) in sweep.iter().enumerate() {
            let ppl = if s == 0.0 {
                dense
            } else {
                p.prune_and_eval(&ctx, method, s)?.0
            };
            ys.push(ppl.ln());
            rows[si][mi] = ppl;
        }
        series.push((method.label().to_string(), ys));
    }
    for (si, &s) in sweep.iter().enumerate() {
        println!(
            "{:.2},{}",
            s,
            rows[si]
                .iter()
                .map(|v| format!("{v:.3}"))
                .collect::<Vec<_>>()
                .join(",")
        );
    }
    println!(
        "{}",
        ascii_chart(
            &format!("log(PPL) vs sparsity — {model}"),
            &sweep,
            &series,
            14
        )
    );
    Ok(())
}
