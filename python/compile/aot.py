"""AOT compiler: lowers every L2 entry (which embed the L1 Pallas kernels)
to HLO *text* artifacts + a manifest the rust coordinator consumes.

Run once at build time (`make artifacts`); python never runs again.

    cd python && python -m compile.aot --out-dir ../artifacts [--only REGEX]

Artifacts per model (6 zoo configs):
    {model}_fwd_loss    (params..., tokens, targets) -> (mean, seq_nll, tok_nll)
    {model}_train_step  (params..., m..., v..., tokens, targets, t, lr)
                        -> (loss, params'..., m'..., v'...)
    {model}_capture     (params..., tokens) -> per-layer Gram/mean stats
    {model}_gradcol     (params..., tokens, targets) -> per-layer Taylor scores
Shared:
    wanda_metric_{m}x{n}   (w, xnorm) -> scores      [L1 pallas kernel]
    gram_{s}x{n}           (x) -> X^T X              [L1 pallas kernel]
    latency_llama_small_s{pct}  sliced decoder layer (speedup bench)

Interchange is HLO text — see aot_util.to_hlo_text for why.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp

from .aot_util import to_hlo_text
from .capture import CAPTURE_LEAVES, capture
from .configs import MODEL_CONFIGS, ModelConfig, param_count, param_spec
from .gradcol import GRADCOL_LEAVES, gradcol
from .latency import layer_fwd_sliced, sliced_dims
from .model import fwd_loss
from .train import train_step
from .kernels.attention import causal_attention
from .kernels.gram import gram
from .kernels.wanda import wanda_scores

F32, I32 = "f32", "i32"


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dt(dtype):
    return I32 if dtype in ("i32", jnp.int32) else F32


class Builder:
    def __init__(self, out_dir: str, only: str | None):
        self.out_dir = out_dir
        self.only = re.compile(only) if only else None
        self.manifest = {
            "format": 1,
            "capture_leaves": CAPTURE_LEAVES,
            "gradcol_leaves": GRADCOL_LEAVES,
            "models": {},
            "artifacts": {},
            "latency": {},
        }

    def want(self, name: str) -> bool:
        return self.only is None or bool(self.only.search(name))

    def add_model(self, cfg: ModelConfig):
        self.manifest["models"][cfg.name] = {
            "family": cfg.family,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "params": [[n, list(s)] for n, s in param_spec(cfg)],
        }

    def emit(self, name: str, fn, in_specs, in_names):
        """Lower fn(*in_specs) and record artifact metadata."""
        if not self.want(name):
            return
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        out_tree = jax.eval_shape(fn, *in_specs)
        leaves = jax.tree_util.tree_leaves(out_tree)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(f"{self.out_dir}/{fname}", "w") as f:
            f.write(text)
        flat_in = jax.tree_util.tree_leaves(in_specs)
        assert len(flat_in) == len(in_names), (name, len(flat_in), len(in_names))
        self.manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                [n, _dt(s.dtype), list(s.shape)]
                for n, s in zip(in_names, flat_in)
            ],
            "outputs": [[_dt(l.dtype), list(l.shape)] for l in leaves],
        }
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO, "
              f"{len(flat_in)} in / {len(leaves)} out, {time.time()-t0:.1f}s",
              flush=True)

    def finish(self):
        path = f"{self.out_dir}/manifest.json"
        if self.only is not None and os.path.exists(path):
            # partial build: merge into the existing manifest instead of
            # clobbering entries the filter skipped
            with open(path) as f:
                old = json.load(f)
            for key in ("artifacts", "models", "latency"):
                merged = old.get(key, {})
                merged.update(self.manifest[key])
                self.manifest[key] = merged
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts")


def build_model_entries(b: Builder, cfg: ModelConfig):
    p_len = param_count(cfg)
    packed = _spec((p_len,))
    state = _spec((3 * p_len,))
    toks = _spec((cfg.batch, cfg.seq), jnp.int32)
    b.emit(
        f"{cfg.name}_fwd_loss",
        fwd_loss(cfg),
        (packed, toks, toks),
        ["params", "tokens", "targets"],
    )
    b.emit(
        f"{cfg.name}_capture",
        capture(cfg),
        (packed, toks),
        ["params", "tokens"],
    )
    b.emit(
        f"{cfg.name}_gradcol",
        gradcol(cfg),
        (packed, toks, toks),
        ["params", "tokens", "targets"],
    )
    scalar = _spec(())
    b.emit(
        f"{cfg.name}_train_step",
        train_step(cfg),
        (state, toks, toks, scalar, scalar),
        ["state", "tokens", "targets", "t", "lr"],
    )


def build_kernel_entries(b: Builder):
    # Wanda metric kernels for every prunable-target shape in the zoo:
    # fc2/down [d, f] and out-proj [d, d].
    shapes = set()
    for cfg in MODEL_CONFIGS.values():
        shapes.add((cfg.d_model, cfg.d_ff))
        shapes.add((cfg.d_model, cfg.d_model))
    for m, n in sorted(shapes):
        b.emit(
            f"wanda_metric_{m}x{n}",
            lambda w, x: (wanda_scores(w, x),),
            (_spec((m, n)), _spec((n,))),
            ["w", "xnorm"],
        )
    # Standalone gram kernels (S = batch*seq rows) for benches/tests.
    cfg = MODEL_CONFIGS["llama_small"]
    s = cfg.batch * cfg.seq
    for n in sorted({cfg.d_model, cfg.d_ff}):
        b.emit(
            f"gram_{s}x{n}",
            lambda x: (gram(x),),
            (_spec((s, n)),),
            ["x"],
        )
    # Flash-attention kernel artifact (single head at llama_small shape).
    dh = cfg.head_dim
    b.emit(
        f"flash_attn_{cfg.seq}x{dh}",
        lambda q, k, v: (causal_attention(q, k, v),),
        (_spec((cfg.seq, dh)), _spec((cfg.seq, dh)), _spec((cfg.seq, dh))),
        ["q", "k", "v"],
    )


def build_latency_entries(b: Builder):
    cfg = MODEL_CONFIGS["llama_small"]
    for pct in (0, 10, 20, 30, 40, 50):
        name = f"latency_llama_small_s{pct}"
        if not b.want(name):
            continue
        fn, shapes = layer_fwd_sliced(cfg, pct / 100.0)
        f_s, dk_s = sliced_dims(cfg, pct / 100.0)
        names = ["x", "ln1_g", "wq", "wk", "wv", "wo",
                 "ln2_g", "w_gate", "w_up", "w_down"]
        b.emit(name, fn, tuple(_spec(s) for s in shapes), names)
        b.manifest["latency"][name] = {
            "sparsity": pct / 100.0, "f_s": f_s, "dk_s": dk_s,
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="regex filter on artifact names")
    args = ap.parse_args()

    b = Builder(args.out_dir, args.only)
    t0 = time.time()
    for cfg in MODEL_CONFIGS.values():
        b.add_model(cfg)
        print(f"model {cfg.name}", flush=True)
        build_model_entries(b, cfg)
    build_kernel_entries(b)
    build_latency_entries(b)
    b.finish()
    print(f"total {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
