"""L2 entry for the LLM-Pruner-like baseline: first-order Taylor column
importance from calibration gradients.

LLM-Pruner scores coupled structures by |W . dL/dW| aggregated over the
structure; we compute, in-graph (so no full gradients ever reach the
host):

  ffn_score [f]  per hidden unit:   sum_i |W2 * g2|[i, j]
                 + coupled row sums of |W1 * g1| (fc1 / gate+up)
  ov_score  [d]  per context dim:   col sums of |Wo * go|
                 + row sums of |Wv * gv|

Output order: layer 0 (ffn_score, ov_score), layer 1 (...), ...
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .model import nll, unpack_params

GRADCOL_LEAVES = ["ffn_score", "ov_score"]


def _taylor(w, g):
    return jnp.abs(w * g)


def gradcol(cfg: ModelConfig):
    def fn(packed, tokens, targets):
        def loss_fn(pk):
            p = unpack_params(cfg, pk)
            return jnp.mean(nll(cfg, p, tokens, targets))

        grad_packed = jax.grad(loss_fn)(packed)
        p = unpack_params(cfg, packed)
        g = unpack_params(cfg, grad_packed)
        outs = []
        for i in range(cfg.n_layers):
            pre = f"layers.{i}."
            if cfg.family == "opt":
                ffn = _taylor(p[pre + "fc2"], g[pre + "fc2"]).sum(axis=0)
                ffn += _taylor(p[pre + "fc1"], g[pre + "fc1"]).sum(axis=1)
            else:
                ffn = _taylor(p[pre + "w_down"], g[pre + "w_down"]).sum(axis=0)
                ffn += _taylor(p[pre + "w_up"], g[pre + "w_up"]).sum(axis=1)
                ffn += _taylor(p[pre + "w_gate"], g[pre + "w_gate"]).sum(axis=1)
            ov = _taylor(p[pre + "wo"], g[pre + "wo"]).sum(axis=0)
            ov += _taylor(p[pre + "wv"], g[pre + "wv"]).sum(axis=1)
            outs += [ffn, ov]
        return tuple(outs)

    return fn
