"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every kernel in this package has a reference here; pytest sweeps shapes and
dtypes (hypothesis) and asserts allclose between kernel and oracle.
"""
from __future__ import annotations

import jax.numpy as jnp


def gram_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Gram matrix of sample rows: x [S, n] -> X^T X [n, n] (sum over S)."""
    return x.T @ x


def wanda_ref(w: jnp.ndarray, xnorm: jnp.ndarray) -> jnp.ndarray:
    """Structured Wanda column score (paper Eq. 7 summed over rows).

    w [m, n] (out,in), xnorm [n] = ||X_j||_2 per input feature.
    score_j = sum_i |W_ij| * xnorm_j = ||W_:,j||_1 * xnorm_j
    """
    return jnp.sum(jnp.abs(w), axis=0) * xnorm


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x [s, k] @ w[out, in=k].T -> [s, out] (PyTorch linear orientation)."""
    return x @ w.T


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Causal single-head attention oracle: q, k, v [S, dh] -> [S, dh]."""
    s, dh = q.shape
    scores = (q @ k.T) / (dh ** 0.5)
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    import jax

    return jax.nn.softmax(scores, axis=-1) @ v
