"""Pallas causal flash-attention kernel (single head).

The paper's serving-side hot spot is the attention block whose V/out dims
FASP prunes; this kernel shows the pruned shapes still compose with a
production-style attention schedule. Flash-style: the query-tile grid
streams K/V tiles through VMEM, keeping a running (max, denominator,
accumulator) triple so the full [S, S] score matrix never materializes.

TPU mapping: grid (S/bq,); per step the kernel holds one [bq, dh] Q tile,
iterates over [bk, dh] K/V tiles with an in-kernel fori_loop (the
HBM→VMEM pipeline the paper's GPU kernels express with warps), and runs
[bq × bk] MXU matmuls. VMEM per step ≈ (bq + 2·bk)·dh + bq·bk floats —
64×64 tiles at dh≤128 stay under 200 KiB.

interpret=True as everywhere (CPU PJRT cannot run Mosaic custom calls).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int, scale: float):
    qi = pl.program_id(0)
    q = q_ref[...]  # [bq, dh]
    s_total = k_ref.shape[0]
    n_kb = s_total // bk
    dh = q.shape[-1]

    def body(kb, carry):
        acc, m_run, l_run = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[...], kb * bk, bk, axis=0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[...], kb * bk, bk, axis=0)
        scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        # causal mask: query row (qi*bq + i) attends keys <= that position
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        scores = jnp.where(k_pos <= q_pos, scores, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(scores, axis=1))
        p = jnp.exp(scores - m_new[:, None])
        correction = jnp.exp(m_run - m_new)
        l_new = l_run * correction + jnp.sum(p, axis=1)
        acc = acc * correction[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, dh), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, _m, l = jax.lax.fori_loop(0, n_kb, body, (acc0, m0, l0))
    o_ref[...] = acc / l[:, None]


def _pick_block(n: int, pref: int) -> int:
    b = min(n, pref)
    while n % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("bq", "bk"))
def causal_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     bq: int = 64, bk: int = 64) -> jnp.ndarray:
    """q, k, v [S, dh] -> out [S, dh], causal, scale 1/sqrt(dh)."""
    s, dh = q.shape
    bq = _pick_block(s, bq)
    bk = _pick_block(s, bk)
    kern = functools.partial(
        _attn_kernel, bq=bq, bk=bk, scale=1.0 / (dh ** 0.5)
    )
    return pl.pallas_call(
        kern,
        grid=(s // bq,),
        in_specs=[
            pl.BlockSpec((bq, dh), lambda i: (i, 0)),
            pl.BlockSpec((s, dh), lambda i: (0, 0)),
            pl.BlockSpec((s, dh), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, dh), jnp.float32),
        interpret=True,
    )(q, k, v)
