"""Pallas tiled matmul kernel (linear layer: y = x @ W^T, W is [out,in]).

Used by the standalone latency artifacts and available to L2 model code;
demonstrates the HBM<->VMEM schedule the paper's GPU kernels expressed with
threadblocks (DESIGN.md §Hardware-Adaptation): grid (s/bs, o/bo, k/bk) with
k innermost; each step drives a [bs,bk]x[bk,bo] MXU matmul accumulating in
the VMEM-resident output tile.

VMEM per step: bs*bk + bo*bk + bs*bo floats = 3*128*128*4 B = 192 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...].T, preferred_element_type=jnp.float32
    )


def _pick_block(n: int, pref: int) -> int:
    b = min(n, pref)
    while n % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("bs", "bo", "bk"))
def linear(x: jnp.ndarray, w: jnp.ndarray,
           bs: int = 128, bo: int = 128, bk: int = 128) -> jnp.ndarray:
    """x [s, k] @ w [o, k].T -> [s, o]."""
    s, kdim = x.shape
    o, kdim2 = w.shape
    assert kdim == kdim2, (x.shape, w.shape)
    bs = _pick_block(s, bs)
    bo = _pick_block(o, bo)
    bk = _pick_block(kdim, bk)
    grid = (s // bs, o // bo, kdim // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bo, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bs, bo), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, o), jnp.float32),
        interpret=True,
    )(x, w)
