"""Pallas kernel for the FASP/Wanda structured column score (paper §3.2).

score_j = sum_i |W_ij| * ||X_j||_2  —  an O(mn) column-abs-sum followed by
an elementwise product with the activation norms.

TPU mapping: grid (n/bn, m/bm) with the row-reduction innermost. Each step
streams a [bm, bn] weight tile through the VPU (abs + column sum — no MXU
needed), accumulating a [bn] partial in the output VMEM tile; the final
row-block multiplies in the xnorm tile. VMEM per step: bm*bn + 2*bn floats
(64 KiB + epsilon at 128x128) — far under budget; the kernel is memory-
bound so tile choice only needs to keep the W stream contiguous.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wanda_kernel(w_ref, xnorm_ref, o_ref, *, last_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(jnp.abs(w_ref[...]), axis=0)

    @pl.when(k == last_k)
    def _finish():
        o_ref[...] *= xnorm_ref[...]


def _pick_block(n: int, pref: int) -> int:
    b = min(n, pref)
    while n % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def wanda_scores(w: jnp.ndarray, xnorm: jnp.ndarray,
                 bm: int = 128, bn: int = 128) -> jnp.ndarray:
    """w [m, n] (out,in), xnorm [n] -> scores [n]."""
    m, n = w.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    grid = (n // bn, m // bm)
    kern = functools.partial(_wanda_kernel, last_k=m // bm - 1)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda j, k: (k, j)),
            pl.BlockSpec((bn,), lambda j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, k: (j,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(w, xnorm)
