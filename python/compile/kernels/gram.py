"""Pallas Gram-accumulation kernel: G = X^T X over sample rows.

This is FASP's calibration hot spot: every decoder layer contributes three
Gram matrices (qkv-input, out-proj-input, fc2/down-input) per calibration
batch; restoration (paper Eq. 8) consumes them and the Wanda metric reads
diag(G) = ||X_j||^2.

TPU mapping (DESIGN.md §Hardware-Adaptation): grid (n/bn, n/bn, S/bs) with
the reduction axis innermost; each step loads two [bs, bn] X tiles into
VMEM and feeds a [bn x bn] MXU matmul, accumulating into the output tile
resident in VMEM across the k-steps (revisiting: out index map ignores k).

Tile choice (EXPERIMENTS.md §Perf iter 3): 256x512 tiles instead of
128x128 — VMEM per step rises to 2*bs*bn + bn*bn = 2*512*256 + 256*256
floats = 1.25 MiB (still ~8% of a 16 MiB core), but the grid shrinks
16x, which matters twice: fewer while-loop iterations under CPU
interpret (the capture artifact dropped ~2.4x end-to-end) and, on real
TPU, fewer HBM revisits of the accumulator tile per unit of work.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are identical, wall-clock is not a TPU proxy.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(x1_ref, x2_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x1_ref[...].T, x2_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(n: int, pref: int) -> int:
    b = min(n, pref)
    while n % b != 0:
        b //= 2
    return max(b, 1)


@functools.partial(jax.jit, static_argnames=("bn", "bs"))
def gram(x: jnp.ndarray, bn: int = 256, bs: int = 512) -> jnp.ndarray:
    """x [S, n] -> X^T X [n, n]. S and n need not be multiples of the
    preferred tile; blocks shrink to the largest power-of-two divisor."""
    s, n = x.shape
    bn = _pick_block(n, bn)
    bs = _pick_block(s, bs)
    grid = (n // bn, n // bn, s // bs)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, bn), lambda i, j, k: (k, i)),
            pl.BlockSpec((bs, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(x, x)
