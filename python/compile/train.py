"""L2 training entry: one fused Adam step over a single packed state
vector, AOT-lowered so the rust trainer feeds the returned state literal
straight back into the next call — no per-tensor decomposition, no python.

State layout: f32[3P] = [params | m | v] (P = packed parameter length,
offsets in configs.param_offsets order).

Entry signature:
  inputs : state f32[3P], tokens i32[B,T], targets i32[B,T],
           t f32[] (1-based step, for Adam bias correction), lr f32[]
  outputs: (loss f32[], state' f32[3P])
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig, param_count
from .model import nll, unpack_params

BETA1, BETA2, EPS = 0.9, 0.999, 1e-8
GRAD_CLIP = 1.0


def train_step(cfg: ModelConfig):
    p_len = param_count(cfg)

    def fn(state, tokens, targets, t, lr):
        params = state[:p_len]
        m = state[p_len:2 * p_len]
        v = state[2 * p_len:]

        def loss_fn(pk):
            p = unpack_params(cfg, pk)
            return jnp.mean(nll(cfg, p, tokens, targets))

        loss, g = jax.value_and_grad(loss_fn)(params)
        gnorm = jnp.sqrt(jnp.sum(g * g) + 1e-12)
        g = g * jnp.minimum(1.0, GRAD_CLIP / gnorm)

        m2 = BETA1 * m + (1.0 - BETA1) * g
        v2 = BETA2 * v + (1.0 - BETA2) * g * g
        mhat = m2 / (1.0 - BETA1 ** t)
        vhat = v2 / (1.0 - BETA2 ** t)
        params2 = params - lr * mhat / (jnp.sqrt(vhat) + EPS)
        return loss, jnp.concatenate([params2, m2, v2])

    return fn
