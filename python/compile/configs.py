"""Model zoo configurations, mirrored by rust/src/model/config.rs.

The zoo spans the paper's two families at three sizes each. The paper used
OPT-{125M,1.3B,2.7B} and LLaMA-{7B,13B,30B}; on the 1-core CPU testbed we
keep the *axes* (family x size x sparsity) and shrink the magnitudes so the
full experiment suite runs in minutes (see DESIGN.md substitution table).

Conventions shared with the rust side:
  * weights are [out, in] (PyTorch orientation); forward computes x @ W.T
  * params are a FLAT LIST of f32 arrays in the exact order produced by
    `param_spec`; the order is exported in artifacts/manifest.json and
    consumed by rust/src/model/weights.rs
  * batch and sequence length are baked into each artifact (static shapes)
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str          # "opt" | "llama"
    d_model: int
    n_heads: int
    n_layers: int
    d_ff: int
    vocab: int
    seq: int = 64
    batch: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


def _opt(name, d, h, l, f, v):
    return ModelConfig(name=name, family="opt", d_model=d, n_heads=h,
                       n_layers=l, d_ff=f, vocab=v)


def _llama(name, d, h, l, f, v):
    return ModelConfig(name=name, family="llama", d_model=d, n_heads=h,
                       n_layers=l, d_ff=f, vocab=v)


# name -> config; sizes: tiny ~0.1M, small ~1M, medium ~7M params
MODEL_CONFIGS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _opt("opt_tiny", 64, 4, 2, 256, 256),
        _opt("opt_small", 128, 4, 4, 512, 512),
        _opt("opt_medium", 256, 8, 6, 1024, 1024),
        _llama("llama_tiny", 64, 4, 2, 256, 256),
        _llama("llama_small", 128, 4, 4, 512, 512),
        _llama("llama_medium", 256, 8, 6, 1024, 1024),
    ]
}


def param_count(cfg: ModelConfig) -> int:
    """Total number of f32 elements across all parameters."""
    total = 0
    for _, shape in param_spec(cfg):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


def param_offsets(cfg: ModelConfig) -> list[tuple[str, int, tuple[int, ...]]]:
    """(name, start offset, shape) for each parameter in the packed vector."""
    out, off = [], 0
    for name, shape in param_spec(cfg):
        n = 1
        for d in shape:
            n *= d
        out.append((name, off, shape))
        off += n
    return out


def param_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Flat, ordered parameter spec. The single source of truth for the
    parameter ordering used by every artifact of this model."""
    d, f, v, t = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq
    spec: list[tuple[str, tuple[int, ...]]] = [("tok_emb", (v, d))]
    if cfg.family == "opt":
        spec.append(("pos_emb", (t, d)))
    for i in range(cfg.n_layers):
        p = f"layers.{i}."
        if cfg.family == "opt":
            spec += [
                (p + "ln1_g", (d,)), (p + "ln1_b", (d,)),
                (p + "wq", (d, d)), (p + "bq", (d,)),
                (p + "wk", (d, d)), (p + "bk", (d,)),
                (p + "wv", (d, d)), (p + "bv", (d,)),
                (p + "wo", (d, d)), (p + "bo", (d,)),
                (p + "ln2_g", (d,)), (p + "ln2_b", (d,)),
                (p + "fc1", (f, d)), (p + "bfc1", (f,)),
                (p + "fc2", (d, f)), (p + "bfc2", (d,)),
            ]
        else:
            # bo / b_down are zero-init "compensation" biases: not part of
            # vanilla LLaMA, but they give FLAP's bias-compensation
            # mechanism a landing spot on this family (DESIGN.md §1).
            spec += [
                (p + "ln1_g", (d,)),
                (p + "wq", (d, d)), (p + "wk", (d, d)),
                (p + "wv", (d, d)), (p + "wo", (d, d)), (p + "bo", (d,)),
                (p + "ln2_g", (d,)),
                (p + "w_gate", (f, d)), (p + "w_up", (f, d)),
                (p + "w_down", (d, f)), (p + "b_down", (d,)),
            ]
    if cfg.family == "opt":
        spec += [("lnf_g", (d,)), ("lnf_b", (d,))]
    else:
        spec += [("lnf_g", (d,))]
    return spec
