"""Shared AOT lowering helpers.

Interchange format is HLO *text*: jax >= 0.5 serializes HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the runtime linked by the
`xla` rust crate) rejects; the HLO text parser reassigns ids and
round-trips cleanly. Lower with return_tuple=True and unwrap on the rust
side.
"""
from __future__ import annotations

from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered object to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
