"""L2 entries for the structured-speedup claim (paper §1/§2: structured
pruning yields inference speedups "achievable on any hardware").

We emit one physically *sliced* LLaMA-style decoder layer per sparsity
level: FASP's coupled structure removes rows/columns, so at sparsity s the
FFN hidden dim shrinks to f_s and the attention V/out dim to dk_s (kept a
multiple of n_heads so heads stay even). Q/K stay dense (FASP skips them).
`bench_layer_latency` measures these artifacts end-to-end on the PJRT CPU
client.

The FFN matmuls route through the L1 Pallas `linear` kernel so the sliced
hot path exercises the same kernel the paper would ship.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.matmul import linear
from .model import rms_norm, rope_tables, apply_rope, causal_attention


def sliced_dims(cfg: ModelConfig, sparsity: float) -> tuple[int, int]:
    """(f_s, dk_s): FFN hidden and attention V/out dims at `sparsity`."""
    f_s = max(cfg.n_heads, int(round(cfg.d_ff * (1.0 - sparsity))))
    dk = int(round(cfg.d_model * (1.0 - sparsity) / cfg.n_heads)) * cfg.n_heads
    dk_s = max(cfg.n_heads, dk)
    return f_s, dk_s


def layer_fwd_sliced(cfg: ModelConfig, sparsity: float):
    """Entry: (x[B,T,d], ln1_g, wq, wk, wv', wo', ln2_g, gate', up', down')
    -> y [B,T,d] where primed weights carry the sliced dims."""
    f_s, dk_s = sliced_dims(cfg, sparsity)
    d, h = cfg.d_model, cfg.n_heads
    dh, dhk = d // h, dk_s // h

    def fn(x, ln1_g, wq, wk, wv, wo, ln2_g, w_gate, w_up, w_down):
        b, t, _ = x.shape
        x_ln = rms_norm(x, ln1_g)
        flat = x_ln.reshape(-1, d)
        q = (flat @ wq.T).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        k = (flat @ wk.T).reshape(b, t, h, dh).transpose(0, 2, 1, 3)
        v = (flat @ wv.T).reshape(b, t, h, dhk).transpose(0, 2, 1, 3)
        cos, sin = rope_tables(t, dh)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        ctx = causal_attention(q, k, v, dh)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(-1, dk_s)
        x = x + (ctx @ wo.T).reshape(b, t, d)
        x_ln2 = rms_norm(x, ln2_g).reshape(-1, d)
        g = linear(x_ln2, w_gate)
        u = linear(x_ln2, w_up)
        hdn = u * jax.nn.silu(g)
        y = linear(hdn, w_down)
        return x + y.reshape(b, t, d)

    shapes = [
        (cfg.batch, cfg.seq, d), (d,),
        (d, d), (d, d), (dk_s, d), (d, dk_s),
        (d,), (f_s, d), (f_s, d), (d, f_s),
    ]
    return fn, shapes
