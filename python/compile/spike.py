"""Spike: de-risk the jax -> HLO-text -> rust/PJRT path for the shapes we need.

Checks (rust side in rust/src/bin or test_runtime):
  1. multi-output functions (tuple root) — how many leaves PJRT returns
  2. i32 inputs (token ids) + gather (embedding lookup)
  3. many parameters (flattened weight list)
Run: python -m compile.spike /root/repo/artifacts/spike.hlo.txt
"""
import sys

import jax
import jax.numpy as jnp

from .aot_util import to_hlo_text


def fn(tokens, emb, w):
    # tokens: i32[2,3], emb: f32[16,4], w: f32[4,4]
    x = emb[tokens]                 # gather
    y = jnp.dot(x, w)
    loss = jnp.mean(y * y)
    seq = jnp.sum(y * y, axis=(1, 2))
    return loss, seq, y             # 3 leaves: f32[], f32[2], f32[2,3,4]


def main():
    out = sys.argv[1] if len(sys.argv) > 1 else "/root/repo/artifacts/spike.hlo.txt"
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((2, 3), jnp.int32),
        jax.ShapeDtypeStruct((16, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = to_hlo_text(lowered)
    with open(out, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars to {out}")


if __name__ == "__main__":
    main()
