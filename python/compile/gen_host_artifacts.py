"""Host-artifact generator: writes artifacts/manifest.json plus one stamp
file per entry for the in-process host runtime backend.

This replaces the original `aot.py` JAX lowering step in offline builds:
the rust runtime executes every entry natively (rust/src/runtime/
host_exec.rs), so an "artifact" is its manifest contract (exact
input/output shapes, identical to what aot.py produced) plus a small
on-disk stamp the loader validates. Entry names, shapes and leaf orders
are byte-compatible with the AOT pipeline so the rust side needs no
special cases.

    cd python && python -m compile.gen_host_artifacts --out-dir ../artifacts

No third-party imports — runs on a bare python3.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# allow running as a plain script too
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.configs import MODEL_CONFIGS, ModelConfig, param_count, param_spec  # noqa: E402
from compile.capture import CAPTURE_LEAVES  # noqa: E402
from compile.gradcol import GRADCOL_LEAVES  # noqa: E402
from compile.latency import sliced_dims  # noqa: E402

MAGIC = "FASP-HOST-ARTIFACT v1"
F32, I32 = "f32", "i32"


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {
            "format": 2,
            "backend": "host",
            "capture_leaves": CAPTURE_LEAVES,
            "gradcol_leaves": GRADCOL_LEAVES,
            "models": {},
            "artifacts": {},
            "latency": {},
        }

    def add_model(self, cfg: ModelConfig):
        self.manifest["models"][cfg.name] = {
            "family": cfg.family,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff,
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "batch": cfg.batch,
            "params": [[n, list(s)] for n, s in param_spec(cfg)],
        }

    def emit(self, name: str, inputs, outputs):
        """inputs: [(name, dtype, shape)], outputs: [(dtype, shape)]."""
        fname = f"{name}.entry.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(f"{MAGIC}\n")
            f.write(f"entry: {name}\n")
            f.write("backend: host\n")
            f.write(f"inputs: {len(inputs)}\n")
            f.write(f"outputs: {len(outputs)}\n")
        self.manifest["artifacts"][name] = {
            "file": fname,
            "kind": "host",
            "inputs": [[n, dt, list(s)] for n, dt, s in inputs],
            "outputs": [[dt, list(s)] for dt, s in outputs],
        }

    def finish(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts")


def model_entries(b: Builder, cfg: ModelConfig):
    p = param_count(cfg)
    bt = [cfg.batch, cfg.seq]
    d, f = cfg.d_model, cfg.d_ff
    b.emit(
        f"{cfg.name}_fwd_loss",
        [("params", F32, [p]), ("tokens", I32, bt), ("targets", I32, bt)],
        [(F32, []), (F32, [cfg.batch]), (F32, bt)],
    )
    cap_out = []
    for _ in range(cfg.n_layers):
        cap_out += [
            (F32, [d, d]), (F32, [d, d]), (F32, [d, d]), (F32, [f, f]),
            (F32, [d]), (F32, [d]), (F32, [d]), (F32, [f]),
        ]
    b.emit(
        f"{cfg.name}_capture",
        [("params", F32, [p]), ("tokens", I32, bt)],
        cap_out,
    )
    grad_out = []
    for _ in range(cfg.n_layers):
        grad_out += [(F32, [f]), (F32, [d])]
    b.emit(
        f"{cfg.name}_gradcol",
        [("params", F32, [p]), ("tokens", I32, bt), ("targets", I32, bt)],
        grad_out,
    )
    b.emit(
        f"{cfg.name}_train_step",
        [
            ("state", F32, [3 * p]),
            ("tokens", I32, bt),
            ("targets", I32, bt),
            ("t", F32, []),
            ("lr", F32, []),
        ],
        [(F32, []), (F32, [3 * p])],
    )


def kernel_entries(b: Builder):
    shapes = set()
    for cfg in MODEL_CONFIGS.values():
        shapes.add((cfg.d_model, cfg.d_ff))
        shapes.add((cfg.d_model, cfg.d_model))
    for m, n in sorted(shapes):
        b.emit(
            f"wanda_metric_{m}x{n}",
            [("w", F32, [m, n]), ("xnorm", F32, [n])],
            [(F32, [n])],
        )
    cfg = MODEL_CONFIGS["llama_small"]
    s = cfg.batch * cfg.seq
    for n in sorted({cfg.d_model, cfg.d_ff}):
        b.emit(f"gram_{s}x{n}", [("x", F32, [s, n])], [(F32, [n, n])])
    dh = cfg.head_dim
    b.emit(
        f"flash_attn_{cfg.seq}x{dh}",
        [("q", F32, [cfg.seq, dh]), ("k", F32, [cfg.seq, dh]), ("v", F32, [cfg.seq, dh])],
        [(F32, [cfg.seq, dh])],
    )


def latency_entries(b: Builder):
    cfg = MODEL_CONFIGS["llama_small"]
    d = cfg.d_model
    for pct in (0, 10, 20, 30, 40, 50):
        name = f"latency_llama_small_s{pct}"
        f_s, dk_s = sliced_dims(cfg, pct / 100.0)
        inputs = [
            ("x", F32, [cfg.batch, cfg.seq, d]),
            ("ln1_g", F32, [d]),
            ("wq", F32, [d, d]),
            ("wk", F32, [d, d]),
            ("wv", F32, [dk_s, d]),
            ("wo", F32, [d, dk_s]),
            ("ln2_g", F32, [d]),
            ("w_gate", F32, [f_s, d]),
            ("w_up", F32, [f_s, d]),
            ("w_down", F32, [d, f_s]),
        ]
        b.emit(name, inputs, [(F32, [cfg.batch, cfg.seq, d])])
        b.manifest["latency"][name] = {
            "sparsity": pct / 100.0, "f_s": f_s, "dk_s": dk_s,
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    b = Builder(args.out_dir)
    for cfg in MODEL_CONFIGS.values():
        b.add_model(cfg)
        model_entries(b, cfg)
    kernel_entries(b)
    latency_entries(b)
    b.finish()
    return 0


if __name__ == "__main__":
    sys.exit(main())
