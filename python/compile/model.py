"""L2: JAX forward/loss for the OPT-style and LLaMA-style model families.

Pure jax (no flax): parameters are a flat list of f32 arrays in
`configs.param_spec` order. Everything here is lowered ONCE by aot.py to
HLO text; python never runs at serving/pruning time.

Architecture notes (mirrors rust/src/model/{opt,llama}.rs, the host
reference used for cross-checking the PJRT path):
  * OPT-style: pre-LN decoder, learned positional embeddings, ReLU FFN,
    LayerNorm with bias, biases on all linears, tied LM head.
  * LLaMA-style: pre-RMSNorm decoder, RoPE on q/k, SwiGLU FFN, no biases,
    tied LM head.
  * Causal MHA; softmax in f32; teacher-forced next-token NLL loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig, param_offsets, param_spec


# ---------------------------------------------------------------- helpers

def params_to_dict(cfg: ModelConfig, flat: list) -> dict:
    spec = param_spec(cfg)
    assert len(flat) == len(spec), (len(flat), len(spec))
    return {name: arr for (name, _), arr in zip(spec, flat)}


def unpack_params(cfg: ModelConfig, packed) -> dict:
    """Unpack a single flat f32[P] vector into the parameter dict.

    The packed layout (param_offsets order) is the runtime currency: the
    rust coordinator ships ONE literal per call instead of ~100, and the
    training state round-trips device-side without per-tensor decomposes.
    XLA fuses the slices away.
    """
    out = {}
    for name, off, shape in param_offsets(cfg):
        n = 1
        for d in shape:
            n *= d
        out[name] = jax.lax.dynamic_slice(packed, (off,), (n,)).reshape(shape)
    return out


def pack_params(cfg: ModelConfig, p: dict):
    """Inverse of unpack_params (used by train_step outputs)."""
    return jnp.concatenate(
        [p[name].reshape(-1) for name, _ in param_spec(cfg)]
    )


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def rms_norm(x, g, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * g


def rope_tables(seq: int, head_dim: int):
    """Rotary embedding cos/sin tables [seq, head_dim/2]."""
    half = head_dim // 2
    inv_freq = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(seq, dtype=jnp.float32)
    freqs = jnp.outer(t, inv_freq)                      # [T, half]
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, cos, sin):
    """x [B, H, T, dh]; rotate-half convention on the dh axis."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def causal_attention(q, k, v, head_dim):
    """q,k,v [B, H, T, dh] -> context [B, H, T, dh]."""
    t = q.shape[2]
    scores = jnp.einsum("bhid,bhjd->bhij", q, k) / jnp.sqrt(
        jnp.float32(head_dim)
    )
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None], scores, jnp.float32(-1e30))
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhij,bhjd->bhid", probs, v)


def _split_heads(x, n_heads):
    b, t, d = x.shape
    return x.reshape(b, t, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


# ---------------------------------------------------------------- forward

def _attn_block(cfg, p, prefix, x_ln, rope):
    """Returns (attn_out_pre_oproj [B,T,d], out [B,T,d]).

    The pre-o-proj context is the calibration input of W_out — the
    activation FASP's out/V coupling and restoration consume.
    """
    d = cfg.d_model
    if cfg.family == "opt":
        q = x_ln @ p[prefix + "wq"].T + p[prefix + "bq"]
        k = x_ln @ p[prefix + "wk"].T + p[prefix + "bk"]
        v = x_ln @ p[prefix + "wv"].T + p[prefix + "bv"]
    else:
        q = x_ln @ p[prefix + "wq"].T
        k = x_ln @ p[prefix + "wk"].T
        v = x_ln @ p[prefix + "wv"].T
    qh, kh, vh = (_split_heads(a, cfg.n_heads) for a in (q, k, v))
    if cfg.family == "llama":
        cos, sin = rope
        qh = apply_rope(qh, cos, sin)
        kh = apply_rope(kh, cos, sin)
    ctx = _merge_heads(causal_attention(qh, kh, vh, cfg.head_dim))
    out = ctx @ p[prefix + "wo"].T + p[prefix + "bo"]
    return ctx, out


def _ffn_block(cfg, p, prefix, x_ln):
    """Returns (ffn2_in [B,T,f], out [B,T,d]).

    ffn2_in is the input of W_fc2 / W_down — the activation FASP's FFN
    coupling, Wanda metric (||X_j||) and restoration Gram consume.
    """
    if cfg.family == "opt":
        h = jax.nn.relu(x_ln @ p[prefix + "fc1"].T + p[prefix + "bfc1"])
        out = h @ p[prefix + "fc2"].T + p[prefix + "bfc2"]
    else:
        g = x_ln @ p[prefix + "w_gate"].T
        u = x_ln @ p[prefix + "w_up"].T
        h = u * jax.nn.silu(g)
        out = h @ p[prefix + "w_down"].T + p[prefix + "b_down"]
    return h, out


def forward_hidden(cfg: ModelConfig, p: dict, tokens, collect=False):
    """tokens i32 [B, T] -> final hidden [B, T, d].

    With collect=True also returns the per-layer calibration activations:
    list of dicts {ln1, ln2, attn_ctx, ffn_h} (pre-flattening shapes)."""
    x = p["tok_emb"][tokens]
    if cfg.family == "opt":
        x = x + p["pos_emb"][None, :, :]
        rope = None
    else:
        rope = rope_tables(cfg.seq, cfg.head_dim)
    captures = []
    for i in range(cfg.n_layers):
        pre = f"layers.{i}."
        if cfg.family == "opt":
            x_ln1 = layer_norm(x, p[pre + "ln1_g"], p[pre + "ln1_b"])
        else:
            x_ln1 = rms_norm(x, p[pre + "ln1_g"])
        ctx, attn_out = _attn_block(cfg, p, pre, x_ln1, rope)
        x = x + attn_out
        if cfg.family == "opt":
            x_ln2 = layer_norm(x, p[pre + "ln2_g"], p[pre + "ln2_b"])
        else:
            x_ln2 = rms_norm(x, p[pre + "ln2_g"])
        h, ffn_out = _ffn_block(cfg, p, pre, x_ln2)
        x = x + ffn_out
        if collect:
            captures.append(
                {"ln1": x_ln1, "ln2": x_ln2, "attn_ctx": ctx, "ffn_h": h}
            )
    if cfg.family == "opt":
        x = layer_norm(x, p["lnf_g"], p["lnf_b"])
    else:
        x = rms_norm(x, p["lnf_g"])
    return (x, captures) if collect else x


def nll(cfg: ModelConfig, p: dict, tokens, targets):
    """Per-token next-token NLL (tied LM head). Returns [B, T] f32."""
    hid = forward_hidden(cfg, p, tokens)
    logits = hid @ p["tok_emb"].T                       # [B, T, V]
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(
        logits, targets[..., None].astype(jnp.int32), axis=-1
    )[..., 0]
    return logz - tgt_logit


def fwd_loss(cfg: ModelConfig):
    """Entry: (packed[P], tokens, targets) -> (mean_nll, seq_nll[B], tok_nll[B,T])."""

    def fn(packed, tokens, targets):
        p = unpack_params(cfg, packed)
        tok = nll(cfg, p, tokens, targets)
        return jnp.mean(tok), jnp.sum(tok, axis=-1), tok

    return fn
