"""L2 calibration-capture entry: one dense forward that accumulates the
per-layer statistics FASP and every baseline consume (DESIGN.md §6).

Per decoder layer we emit (sums over the B*T sample rows, additive across
calibration batches so the rust coordinator can stream batches):

  G_ln1   [d, d]  Gram of the qkv input      (SliceGPT PCA, QK ablation)
  G_ln2   [d, d]  Gram of the fc1/gate input (SliceGPT PCA, FLAP)
  G_attn  [d, d]  Gram of the W_out input    (FASP out/V restoration)
  G_ffn   [f, f]  Gram of the fc2/down input (FASP FFN restoration;
                  diag is the Wanda ||X_j||^2)
  m_ln1/m_ln2/m_attn/m_ffn  column sums (means for FLAP fluctuation and
                  bias compensation)

All four Grams go through the L1 Pallas `gram` kernel so the paper's
calibration hot spot lowers into this artifact's HLO.
"""
from __future__ import annotations

import jax.numpy as jnp

from .configs import ModelConfig
from .kernels.gram import gram
from .model import forward_hidden, unpack_params

# Per-layer leaf names, in emission order (manifest + rust mirror this).
CAPTURE_LEAVES = [
    "G_ln1", "G_ln2", "G_attn", "G_ffn",
    "m_ln1", "m_ln2", "m_attn", "m_ffn",
]


def capture(cfg: ModelConfig):
    """Entry: (packed[P], tokens) -> flat per-layer stats tuple.

    Output order: layer 0 leaves (CAPTURE_LEAVES order), layer 1 leaves, ...
    """

    def fn(packed, tokens):
        p = unpack_params(cfg, packed)
        _, caps = forward_hidden(cfg, p, tokens, collect=True)
        outs = []
        for cap in caps:
            ln1 = cap["ln1"].reshape(-1, cfg.d_model)
            ln2 = cap["ln2"].reshape(-1, cfg.d_model)
            ctx = cap["attn_ctx"].reshape(-1, cfg.d_model)
            ffn = cap["ffn_h"].reshape(-1, cfg.d_ff)
            outs += [
                gram(ln1), gram(ln2), gram(ctx), gram(ffn),
                jnp.sum(ln1, axis=0), jnp.sum(ln2, axis=0),
                jnp.sum(ctx, axis=0), jnp.sum(ffn, axis=0),
            ]
        return tuple(outs)

    return fn
