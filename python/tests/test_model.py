"""L2 correctness: model entries (shapes, loss sanity, training signal,
capture/gradcol contracts) for both families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.capture import CAPTURE_LEAVES, capture
from compile.configs import MODEL_CONFIGS, param_count, param_offsets, param_spec
from compile.gradcol import gradcol
from compile.model import fwd_loss, pack_params, unpack_params
from compile.train import train_step

TINY = ["opt_tiny", "llama_tiny"]


def make_params(cfg, seed=0, scale=0.05):
    key = jax.random.PRNGKey(seed)
    chunks = []
    for i, (name, shape) in enumerate(param_spec(cfg)):
        k = jax.random.fold_in(key, i)
        if name.endswith("_g"):
            chunks.append(jnp.ones(shape).reshape(-1))
        else:
            chunks.append((jax.random.normal(k, shape) * scale).reshape(-1))
    return jnp.concatenate(chunks)


def make_tokens(cfg, seed=1):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (cfg.batch, cfg.seq), 0, cfg.vocab)


@pytest.mark.parametrize("name", TINY)
def test_fwd_loss_shapes_and_range(name):
    cfg = MODEL_CONFIGS[name]
    packed = make_params(cfg)
    toks = make_tokens(cfg)
    mean, seq, tok = jax.jit(fwd_loss(cfg))(packed, toks, toks)
    assert seq.shape == (cfg.batch,)
    assert tok.shape == (cfg.batch, cfg.seq)
    # random init ⇒ loss near log(V)
    assert abs(float(mean) - np.log(cfg.vocab)) < 1.0
    np.testing.assert_allclose(float(jnp.mean(tok)), float(mean), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.sum(tok, axis=-1)), np.asarray(seq), rtol=1e-5)


@pytest.mark.parametrize("name", TINY)
def test_pack_unpack_roundtrip(name):
    cfg = MODEL_CONFIGS[name]
    packed = make_params(cfg)
    d = unpack_params(cfg, packed)
    repacked = pack_params(cfg, d)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(repacked))
    # offsets consistent with spec order
    off = param_offsets(cfg)
    assert off[0][1] == 0
    assert sum(int(np.prod(s)) for _, _, s in off) == param_count(cfg)


@pytest.mark.parametrize("name", TINY)
def test_capture_gram_is_gram(name):
    """The capture artifact's G_ffn must equal X^T X of the actual hidden
    activations — checked against an independent recomputation."""
    cfg = MODEL_CONFIGS[name]
    packed = make_params(cfg)
    toks = make_tokens(cfg)
    outs = jax.jit(capture(cfg))(packed, toks)
    per = len(CAPTURE_LEAVES)
    assert len(outs) == per * cfg.n_layers
    for l in range(cfg.n_layers):
        g_ffn = outs[l * per + 3]
        assert g_ffn.shape == (cfg.d_ff, cfg.d_ff)
        g = np.asarray(g_ffn)
        # symmetric PSD
        np.testing.assert_allclose(g, g.T, rtol=1e-4, atol=1e-2)
        evals = np.linalg.eigvalsh(g.astype(np.float64))
        assert evals.min() > -1e-2 * max(1.0, evals.max())
        # diag(G) are squared norms ⇒ non-negative
        assert np.diag(g).min() >= -1e-4


@pytest.mark.parametrize("name", TINY)
def test_gradcol_scores_nonnegative(name):
    cfg = MODEL_CONFIGS[name]
    packed = make_params(cfg)
    toks = make_tokens(cfg)
    outs = jax.jit(gradcol(cfg))(packed, toks, toks)
    assert len(outs) == 2 * cfg.n_layers
    for l in range(cfg.n_layers):
        assert outs[2 * l].shape == (cfg.d_ff,)
        assert outs[2 * l + 1].shape == (cfg.d_model,)
        assert float(jnp.min(outs[2 * l])) >= 0.0
        assert float(jnp.min(outs[2 * l + 1])) >= 0.0


@pytest.mark.parametrize("name", TINY)
def test_train_step_decreases_loss(name):
    cfg = MODEL_CONFIGS[name]
    p = param_count(cfg)
    packed = make_params(cfg)
    state = jnp.concatenate([packed, jnp.zeros(p), jnp.zeros(p)])
    toks = make_tokens(cfg)
    tgts = make_tokens(cfg, seed=2)
    step = jax.jit(train_step(cfg))
    loss0, state = step(state, toks, tgts, jnp.float32(1.0), jnp.float32(5e-3))
    lossn = loss0
    for i in range(2, 12):
        lossn, state = step(state, toks, tgts, jnp.float32(i), jnp.float32(5e-3))
    assert float(lossn) < float(loss0) - 0.05, (float(loss0), float(lossn))


def test_opt_and_llama_differ():
    """Families must be genuinely different architectures."""
    co, cl = MODEL_CONFIGS["opt_tiny"], MODEL_CONFIGS["llama_tiny"]
    names_o = {n for n, _ in param_spec(co)}
    names_l = {n for n, _ in param_spec(cl)}
    assert "pos_emb" in names_o and "pos_emb" not in names_l
    assert "layers.0.w_gate" in names_l and "layers.0.w_gate" not in names_o
