"""AOT contract tests: manifest consistency and HLO-text generation."""
import json
import os
import pathlib

import jax
import jax.numpy as jnp
import pytest

from compile.aot_util import to_hlo_text
from compile.configs import MODEL_CONFIGS, param_count
from compile.latency import sliced_dims

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


def test_hlo_text_roundtrippable():
    def fn(x):
        return (x * 2.0 + 1.0,)

    low = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = to_hlo_text(low)
    assert "HloModule" in text
    assert "ROOT" in text


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_manifest_consistent_with_configs():
    man = json.loads((ART / "manifest.json").read_text())
    assert set(man["models"]) == set(MODEL_CONFIGS)
    for name, cfg in MODEL_CONFIGS.items():
        m = man["models"][name]
        assert m["d_model"] == cfg.d_model
        assert m["n_layers"] == cfg.n_layers
        total = sum(int(jnp.prod(jnp.array(s))) for _, s in m["params"])
        assert total == param_count(cfg)
        # four entries per model
        for entry in ["fwd_loss", "capture", "gradcol", "train_step"]:
            art = man["artifacts"][f"{name}_{entry}"]
            assert (ART / art["file"]).exists()
    # every artifact's file exists and is non-trivial HLO text
    for art in man["artifacts"].values():
        path = ART / art["file"]
        assert path.exists(), path
        head = path.read_text()[:200]
        assert "HloModule" in head


@pytest.mark.skipif(not (ART / "manifest.json").exists(), reason="run `make artifacts` first")
def test_train_step_io_shapes():
    man = json.loads((ART / "manifest.json").read_text())
    for name, cfg in MODEL_CONFIGS.items():
        art = man["artifacts"][f"{name}_train_step"]
        p = param_count(cfg)
        ins = art["inputs"]
        assert ins[0] == ["state", "f32", [3 * p]]
        assert ins[1] == ["tokens", "i32", [cfg.batch, cfg.seq]]
        outs = art["outputs"]
        assert outs[0] == ["f32", []]
        assert outs[1] == ["f32", [3 * p]]


def test_sliced_dims_monotone():
    cfg = MODEL_CONFIGS["llama_small"]
    prev = (cfg.d_ff + 1, cfg.d_model + 1)
    for pct in (0, 10, 20, 30, 40, 50):
        f_s, dk_s = sliced_dims(cfg, pct / 100.0)
        assert f_s <= prev[0] and dk_s <= prev[1]
        assert dk_s % cfg.n_heads == 0
        prev = (f_s, dk_s)
