"""L1 correctness: Pallas kernels vs pure-jnp oracles, hypothesis-swept
over shapes and value ranges. This is the kernel-level correctness signal
the whole stack rests on."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import causal_attention
from compile.kernels.gram import gram
from compile.kernels.matmul import linear
from compile.kernels.wanda import wanda_scores

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


@hypothesis.given(
    s=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_gram_matches_ref(s, n, seed):
    x = rand(seed, (s, n))
    got = gram(x)
    want = ref.gram_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@hypothesis.given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_wanda_matches_ref(m, n, seed):
    w = rand(seed, (m, n))
    xn = jnp.abs(rand(seed + 1, (n,)))
    got = wanda_scores(w, xn)
    want = ref.wanda_ref(w, xn)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@hypothesis.given(
    s=st.integers(1, 80),
    k=st.integers(1, 80),
    o=st.integers(1, 80),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_matmul_matches_ref(s, k, o, seed):
    x = rand(seed, (s, k))
    w = rand(seed + 1, (o, k))
    got = linear(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@hypothesis.given(
    s=st.integers(2, 64),
    dh=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**SETTINGS)
def test_attention_matches_ref(s, dh, seed):
    q = rand(seed, (s, dh))
    k = rand(seed + 1, (s, dh))
    v = rand(seed + 2, (s, dh))
    got = causal_attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_attention_is_causal():
    # perturbing future K/V must not change earlier outputs
    s, dh = 32, 16
    q, k, v = rand(1, (s, dh)), rand(2, (s, dh)), rand(3, (s, dh))
    base = causal_attention(q, k, v)
    k2 = k.at[-1].set(99.0)
    v2 = v.at[-1].set(-99.0)
    pert = causal_attention(q, k2, v2)
    np.testing.assert_allclose(base[: s - 1], pert[: s - 1], rtol=1e-5, atol=1e-5)


def test_attention_rows_are_convex_combos():
    # with v = const c, output must be exactly c
    s, dh = 16, 8
    q, k = rand(4, (s, dh)), rand(5, (s, dh))
    v = jnp.full((s, dh), 3.5)
    out = causal_attention(q, k, v)
    np.testing.assert_allclose(out, v, rtol=1e-5)


def test_gram_large_block_shapes():
    # exercise the 128-tile fast path exactly
    x = rand(7, (512, 256))
    np.testing.assert_allclose(gram(x), ref.gram_ref(x), rtol=1e-4, atol=5e-3)


def test_wanda_zero_weight_gives_zero_scores():
    w = jnp.zeros((32, 16))
    xn = jnp.ones((16,))
    assert float(jnp.max(jnp.abs(wanda_scores(w, xn)))) == 0.0


def test_gram_psd():
    x = rand(3, (64, 32))
    g = np.asarray(gram(x))
    evals = np.linalg.eigvalsh(g)
    assert evals.min() > -1e-3


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_matmul_dtype(dtype):
    x = rand(0, (16, 16)).astype(dtype)
    w = rand(1, (16, 16)).astype(dtype)
    assert linear(x, w).dtype == jnp.float32
