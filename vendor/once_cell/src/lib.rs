//! Offline mini-`once_cell`: the two cell types this repo uses, aliased
//! onto their std equivalents (stable since Rust 1.70) so the build needs
//! no crates.io access.

pub mod sync {
    /// Thread-safe once-initialized cell (`std::sync::OnceLock` has the
    /// same `new`/`get`/`set`/`get_or_init` surface as once_cell's).
    pub type OnceCell<T> = std::sync::OnceLock<T>;
}

pub mod unsync {
    /// Single-threaded once-initialized cell.
    pub type OnceCell<T> = std::cell::OnceCell<T>;
}

#[cfg(test)]
mod tests {
    #[test]
    fn sync_cell_works() {
        let c: super::sync::OnceCell<u32> = super::sync::OnceCell::new();
        assert!(c.get().is_none());
        assert!(c.set(7).is_ok());
        assert_eq!(*c.get_or_init(|| 9), 7);
    }

    #[test]
    fn unsync_cell_works() {
        let c: super::unsync::OnceCell<String> = super::unsync::OnceCell::new();
        assert_eq!(c.get_or_init(|| "x".to_string()), "x");
    }
}
