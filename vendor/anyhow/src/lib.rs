//! Offline mini-`anyhow`: the subset of the real crate's API that this
//! repository uses, implemented with no dependencies so the build works
//! without a crates.io mirror (same policy as the in-repo serde/rand
//! substitutes — see rust/src/util/mod.rs).
//!
//! Supported surface:
//! * `anyhow::Error` — a context chain of messages (outermost first).
//! * `anyhow::Result<T>` — alias with `Error` as the default error.
//! * `anyhow!`, `bail!`, `ensure!` — format-style constructors.
//! * `Context` — `.context(..)` / `.with_context(..)` on `Result` (for
//!   any error convertible into `Error`, including `Error` itself) and on
//!   `Option`.
//! * `Display` prints the outermost message; `{:#}` prints the full chain
//!   joined by `": "`; `Debug` prints the chain as a `Caused by:` list —
//!   all matching real-anyhow semantics closely enough for log/grep use.

use std::fmt;

/// Error: an ordered chain of human-readable messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Push a new outermost context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// The full chain, outermost first.
    pub fn chain_messages(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: full chain, "outer: inner: root"
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

/// Any std error converts into `Error`, capturing its source chain.
/// (`Error` itself deliberately does NOT implement `std::error::Error`,
/// exactly like real anyhow, so this blanket impl cannot self-overlap.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(c)),
        }
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(c)),
        }
    }
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing thing");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u8> = None;
        let e = none.context("absent").unwrap_err();
        assert_eq!(format!("{e}"), "absent");

        fn fails(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert!(fails(5).is_ok());
        assert_eq!(format!("{}", fails(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", fails(200).unwrap_err()), "too big: 200");
        let e = anyhow!("ad hoc {}", 7);
        assert_eq!(format!("{e}"), "ad hoc 7");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner");
    }
}
